"""Tests for the flat (J, P) wire format of the federated runtime.

Covers:
  * ``TreeSpec`` — the pytree <-> one-f32-vector bijection (structure,
    dtypes, jit-safety, empty-subtree edge cases);
  * flat vs legacy wire equivalence: without DP/compression the packed
    path is a pure relayout, so trajectories must agree BIT FOR BIT;
  * fused vs flat wire equivalence end to end: the fused Pallas kernels
    replay the SAME op sequence and the SAME DP noise stream inside the
    compiled round, so trajectories agree bit for bit without
    compression and under DP — and stay tolerance-close when int8
    requantization + async fractional weights reorder the arithmetic;
  * save -> resume ACROSS a wire-mode change (flat <-> fused);
  * wire accounting: one int8 scale per SILO (not per leaf) on the flat
    path;
  * the compiled-graph invariance (subprocess, 4 forced host devices):
    a DP + int8 round lowers to exactly ONE all_gather per wire dtype
    (s8 payload + f32 scale), and an uncompressed round to exactly one
    f32 gather — the §3.2 exchange structure, on BOTH the flat and the
    fused wire.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ConditionalGaussian,
    DiagGaussian,
    SFVIProblem,
    StructuredModel,
)
from repro.core.flatten import TreeSpec
from repro.federated import (
    AsyncConfig,
    Experiment,
    ExperimentSpec,
    FamilySpec,
    Int8Compressor,
    ModelSpec,
    NoCompression,
    OptimizerSpec,
    PrivacyPolicy,
    Scenario,
    Server,
    build,
)
from repro.optim.sgd import sgd

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestTreeSpec:
    def _tree(self):
        return {
            "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray(2.5), "d": jnp.ones((4,), jnp.float32)},
        }

    def test_round_trip_preserves_structure_and_values(self):
        tree = self._tree()
        spec = TreeSpec.of(tree)
        vec = spec.pack(tree)
        assert vec.shape == (spec.dim,) == (11,)
        assert vec.dtype == jnp.float32
        back = spec.unpack(vec)
        assert jax.tree_util.tree_structure(back) == \
            jax.tree_util.tree_structure(tree)
        for x, y in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(back), strict=True):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            assert x.dtype == y.dtype

    def test_empty_subtree_and_empty_tree(self):
        tree = {"theta": {}, "eta": {"mu": jnp.ones((3,))}}
        spec = TreeSpec.of(tree)
        assert spec.dim == 3
        back = spec.unpack(spec.pack(tree))
        assert back["theta"] == {}
        empty = TreeSpec.of({})
        assert empty.dim == 0
        assert empty.pack({}).shape == (0,)

    def test_jittable_and_static(self):
        tree = self._tree()
        spec = TreeSpec.of(tree)
        assert hash(spec) == hash(TreeSpec.of(self._tree()))
        vec = jax.jit(spec.pack)(tree)
        back = jax.jit(spec.unpack)(vec)
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree["a"]))

    def test_seeded_random_sweep(self):
        rng = np.random.default_rng(0)
        for _ in range(20):
            n = int(rng.integers(1, 5))
            tree = {
                f"k{i}": jnp.asarray(
                    rng.normal(size=tuple(rng.integers(1, 4, size=int(
                        rng.integers(0, 3))))).astype(np.float32))
                for i in range(n)
            }
            spec = TreeSpec.of(tree)
            back = spec.unpack(spec.pack(tree))
            for k in tree:
                np.testing.assert_array_equal(np.asarray(tree[k]),
                                              np.asarray(back[k]))


def _hier_problem(dG=3, dL=2):
    model = StructuredModel(
        global_dim=dG, local_dim=dL,
        log_prior_global=lambda th, zg: -0.5 * jnp.sum((zg - th["m"]) ** 2),
        log_local=lambda th, zg, zl, d: (
            -0.5 * jnp.sum((zl - jnp.mean(zg)) ** 2)
            - 0.5 * jnp.sum((d["y"] - zl[None, :]) ** 2)
        ),
    )
    return SFVIProblem(
        model, DiagGaussian(dG), ConditionalGaussian(dL, dG, use_coupling=False)
    )


def _server(wire, compressor=None, privacy=None, seed=11):
    prob = _hier_problem()
    datas = [{"y": jax.random.normal(
        jax.random.fold_in(jax.random.PRNGKey(9), j), (4, 2))}
        for j in range(3)]
    return Server(
        prob, datas, {"m": jnp.asarray(0.2)},
        prob.global_family.init(jax.random.PRNGKey(1)),
        server_opt=sgd(3e-2), local_opt=sgd(3e-2),
        compressor=compressor, privacy=privacy, wire=wire, seed=seed,
    )


def _flat(tree):
    leaves = [np.ravel(np.asarray(x))
              for x in jax.tree_util.tree_leaves(tree)]
    return np.concatenate(leaves) if leaves else np.zeros((0,), np.float32)


class TestFlatVsLegacy:
    @pytest.mark.parametrize("algorithm", ["sfvi", "sfvi_avg"])
    def test_bit_exact_without_dp_or_compression(self, algorithm):
        """Packing is a relayout: flat and legacy wires must produce the
        SAME trajectory bit for bit when no codec/noise touches the
        payload (per-coordinate reduction order is unchanged)."""
        a, b = _server("flat"), _server("legacy")
        a.run(3, algorithm=algorithm, local_steps=2)
        b.run(3, algorithm=algorithm, local_steps=2)
        for k in ("theta", "eta_G", "eta_L"):
            np.testing.assert_array_equal(_flat(a.state[k]), _flat(b.state[k]))

    def test_int8_flat_close_to_legacy(self):
        """One scale per silo instead of per leaf changes quantization
        noise, not semantics: trajectories stay close."""
        a = _server("flat", compressor=Int8Compressor())
        b = _server("legacy", compressor=Int8Compressor())
        a.run(3, algorithm="sfvi_avg", local_steps=2)
        b.run(3, algorithm="sfvi_avg", local_steps=2)
        np.testing.assert_allclose(_flat(a.eta_G), _flat(b.eta_G),
                                   rtol=0.05, atol=0.05)

    def test_rejects_unknown_wire(self):
        with pytest.raises(ValueError, match="wire layout"):
            _server("pigeon")


def _toy_spec(scenario, *, gfam=None, rounds=4):
    return ExperimentSpec(
        model=ModelSpec("toy", {"num_obs": 6}, global_family=gfam),
        scenario=scenario, num_silos=4, rounds=rounds, local_steps=2,
        server_opt=OptimizerSpec("adam", 2e-2), seed=3,
    )


class TestFusedVsFlat:
    """The fused Pallas wire against the flat reference, end to end.

    The equivalence contract (docs/federated.md): bit-exact whenever no
    requantization reorders arithmetic — including under DP, because the
    kernel draws the SAME per-silo noise stream in-kernel — and
    tolerance-equal once int8 + async fractional weights are live.
    """

    @pytest.mark.parametrize("algorithm", ["sfvi", "sfvi_avg"])
    def test_bit_exact_without_dp_or_compression(self, algorithm):
        a, b = _server("flat"), _server("fused")
        a.run(3, algorithm=algorithm, local_steps=2)
        b.run(3, algorithm=algorithm, local_steps=2)
        for k in ("theta", "eta_G", "eta_L"):
            np.testing.assert_array_equal(_flat(a.state[k]), _flat(b.state[k]))

    @pytest.mark.parametrize("algorithm", ["sfvi", "sfvi_avg"])
    def test_bit_exact_under_dp(self, algorithm):
        """In-kernel noise is the same stream PrivacyPolicy draws (same
        round key -> same folded per-silo keys -> same normals), and the
        clip/add pipeline is the same op sequence — so even DP
        trajectories agree bit for bit."""
        pol = PrivacyPolicy(clip_norm=0.8, noise_multiplier=0.7)
        a = _server("flat", privacy=pol)
        b = _server("fused", privacy=pol)
        a.run(3, algorithm=algorithm, local_steps=2)
        b.run(3, algorithm=algorithm, local_steps=2)
        for k in ("theta", "eta_G", "eta_L"):
            np.testing.assert_array_equal(_flat(a.state[k]), _flat(b.state[k]))

    def test_dp_int8_async_fractional_weights_close(self):
        """int8 requantization happens at a different point in the fused
        pipeline (one fused pass vs encode-then-decode), so under the
        full stack — DP + int8 + buffered-async fractional weights +
        trimmed aggregation — the contract relaxes to tolerance."""
        sc = Scenario(algorithm="sfvi_avg", compression="int8",
                      dp_noise=0.4, dp_clip=0.9,
                      aggregator="trimmed", trim_frac=0.2,
                      async_cfg=AsyncConfig(buffer_size=2,
                                            latency="lognormal"))
        spec = _toy_spec(sc, rounds=5)
        a, b = build(spec, wire="flat"), build(spec, wire="fused")
        a.run()
        b.run()
        np.testing.assert_allclose(_flat(a.eta_G), _flat(b.eta_G),
                                   rtol=0.05, atol=0.05)
        np.testing.assert_allclose(np.asarray(a.history["elbo"]),
                                   np.asarray(b.history["elbo"]),
                                   rtol=0.05, atol=0.5)

    def test_full_covariance_barycenter_bit_exact(self):
        """sfvi_avg with a CholeskyGaussian global family routes the
        barycenter's matrix sqrt through the fused Newton-Schulz kernel
        — same normalization, same iteration, bit-identical states."""
        spec = _toy_spec(Scenario(algorithm="sfvi_avg"),
                         gfam=FamilySpec("cholesky"), rounds=3)
        a, b = build(spec, wire="flat"), build(spec, wire="fused")
        a.run()
        b.run()
        for k in ("theta", "eta_G", "eta_L"):
            np.testing.assert_array_equal(_flat(a.server.state[k]),
                                          _flat(b.server.state[k]))

    @pytest.mark.parametrize("wires", [("flat", "fused"), ("fused", "flat")])
    def test_resume_across_wire_mode_change(self, tmp_path, wires):
        """A checkpoint taken on one wire continues on the other with no
        trajectory change (no DP/compression -> both wires are the same
        bit-exact program), via Experiment.resume(..., wire=...)."""
        first, second = wires
        spec = _toy_spec(Scenario(algorithm="sfvi_avg"), rounds=4)
        full = build(spec, wire=first)
        full.run()

        part = build(spec, wire=first)
        part.run(2)
        part.save(str(tmp_path))
        resumed = Experiment.resume(str(tmp_path), wire=second)
        assert resumed.server.wire == second
        resumed.run()
        for k in ("theta", "eta_G", "eta_L"):
            np.testing.assert_array_equal(
                _flat(full.server.state[k]), _flat(resumed.server.state[k]))

    def test_resume_defaults_to_recorded_wire(self, tmp_path):
        spec = _toy_spec(Scenario(algorithm="sfvi_avg"), rounds=3)
        part = build(spec, wire="fused")
        part.run(1)
        part.save(str(tmp_path))
        assert Experiment.resume(str(tmp_path)).server.wire == "fused"


class TestWireAccounting:
    def test_int8_pays_one_scale_per_silo(self):
        srv = _server("flat", compressor=Int8Compressor())
        P = srv.wire_spec("sfvi").dim
        assert srv.bytes_up_per_silo("sfvi") == P + 4  # payload + ONE scale
        legacy = _server("legacy", compressor=Int8Compressor())
        n_leaves = len(jax.tree_util.tree_leaves(legacy.ship_template("sfvi")))
        assert legacy.bytes_up_per_silo("sfvi") == P + 4 * n_leaves
        assert n_leaves > 1  # the saving is real

    def test_uncompressed_bytes_identical_across_wires(self):
        flat, legacy = _server("flat"), _server("legacy")
        for algo in ("sfvi", "sfvi_avg"):
            assert flat.bytes_up_per_silo(algo) == \
                legacy.bytes_up_per_silo(algo) == \
                NoCompression().wire_bytes(flat.ship_template(algo))


# ---------------------------------------------------------------------------
# Compiled-graph invariance: one all_gather per wire dtype (subprocess)
# ---------------------------------------------------------------------------

_HLO_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")
    import re
    import jax, jax.numpy as jnp
    from repro.core import (ConditionalGaussian, DiagGaussian, SFVIProblem,
                            StructuredModel)
    from repro.federated import Int8Compressor, PrivacyPolicy, Server
    from repro.optim.adam import adam

    model = StructuredModel(
        global_dim=3, local_dim=2,
        log_prior_global=lambda th, zg: -0.5 * jnp.sum((zg - th["m"]) ** 2),
        log_local=lambda th, zg, zl, d: (
            -0.5 * jnp.sum((zl - jnp.mean(zg)) ** 2)
            - 0.5 * jnp.sum((d["y"] - zl[None, :]) ** 2)),
    )
    prob = SFVIProblem(model, DiagGaussian(3),
                       ConditionalGaussian(2, 3, use_coupling=False))
    datas = [{"y": jax.random.normal(
        jax.random.fold_in(jax.random.PRNGKey(2), j), (4, 2))}
        for j in range(4)]
    pol = PrivacyPolicy(clip_norm=1.0, noise_multiplier=1.0)

    def gathers_by_dtype(hlo):
        # one entry per all-gather instruction: its result element type.
        out = {}
        for m in re.finditer(
                r"= (\\w+)\\[[0-9,]*\\](?:\\{[^}]*\\})? "
                r"all-gather(?:-start)?\\(", hlo):
            out[m.group(1)] = out.get(m.group(1), 0) + 1
        return out

    for wire in ("flat", "fused"):
        for comp, expect in ((Int8Compressor(), {"s8": 1, "f32": 1}),
                             (None, {"f32": 1})):
            for algo, K in (("sfvi", 2), ("sfvi_avg", 3)):
                srv = Server(prob, datas, {"m": jnp.asarray(0.1)},
                             prob.global_family.init(jax.random.PRNGKey(1)),
                             server_opt=adam(1e-2), local_opt=adam(1e-2),
                             compressor=comp, privacy=pol, wire=wire, seed=0)
                assert srv.wire == wire
                fn = srv._get_round(algo, K)
                mask_shape = (K, 4) if algo == "sfvi" else (4,)
                ones = jnp.ones(mask_shape, jnp.float32)
                args = (srv.state, srv.data, jnp.asarray(srv.num_obs),
                        jax.random.PRNGKey(0), ones, ones)
                hlo = fn.lower(*args).compile().as_text()
                got = gathers_by_dtype(hlo)
                assert got == expect, (wire, algo, K, type(comp).__name__,
                                       got, expect)
                print(wire, algo, K, type(comp).__name__, "OK", got)
""")


@pytest.mark.slow
def test_flat_round_compiles_to_one_gather_per_wire_dtype():
    """Flat AND fused wires preserve the §3.2 exchange structure in the
    optimized HLO: a DP + int8 round is exactly one s8 all_gather (the
    payload matrix) plus one f32 all_gather (the per-silo scales), an
    uncompressed DP round exactly one f32 all_gather — independent of
    algorithm and local_steps, on a real 4-device mesh. (The fused
    kernels change what happens per shard, not what crosses the wire.)
    """
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _HLO_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1200,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert out.stdout.count("OK") == 8, out.stdout
