"""Fused wire-kernel validation (``kernels/wire.py`` via ``kernels/ops``).

Three kernels fuse the federated round's wire hot path — per-silo
clip + DP noise + int8 quantize over the (J, P) matrix, the masked /
weighted (trimmed-)mean reduction, and the Newton–Schulz sqrt step —
and each is pinned to a pure-jnp oracle in ``kernels/ref.py`` plus the
live runtime component it replaces (PrivacyPolicy, the aggregators,
core.barycenter's sqrtm).

Comparisons are JIT vs JIT: the runtime only ever executes these stages
inside the compiled round, and eager-mode XLA contracts FMAs
differently (a 1-ulp artifact, not a semantic difference), so the
honest bit-exactness contract is between compiled programs. Kernels run
in interpret mode on CPU; hypothesis is optional — without it the
property sweeps degrade to fixed seeded parameter grids over the same
domain (same shapes drawn, fewer of them).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.barycenter import sqrtm_newton_schulz
from repro.federated.aggregation import MeanAggregator, TrimmedMeanAggregator
from repro.federated.privacy import PrivacyPolicy
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)

# J deliberately includes primes (no block divides them except 1) and
# P values that are not multiples of any kernel block size, so the
# block-partitioning logic is exercised, not just the aligned fast path.
SHAPES = [(1, 1), (2, 3), (3, 64), (4, 8), (7, 129), (13, 257), (16, 512)]


def _mat(shape, dtype=jnp.float32, salt=0):
    return jax.random.normal(
        jax.random.fold_in(KEY, salt), shape, jnp.float32).astype(dtype)


def _mask(J, pattern, salt=0):
    if pattern == "all":
        return jnp.ones((J,), jnp.float32)
    if pattern == "none":
        return jnp.zeros((J,), jnp.float32)
    bits = jax.random.bernoulli(jax.random.fold_in(KEY, 100 + salt), 0.6, (J,))
    return bits.astype(jnp.float32)


def _keys(J, salt=0):
    base = jax.random.fold_in(KEY, 200 + salt)
    return jax.vmap(lambda j: jax.random.fold_in(base, j))(jnp.arange(J))


def _exact(a, b):
    if isinstance(a, tuple):
        for x, y in zip(a, b, strict=True):
            _exact(x, y)
        return
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fused upload: clip + noise + mask + quantize
# ---------------------------------------------------------------------------

UPLOAD_CONFIGS = [
    # (clip_norm, noise_multiplier, quantize, use_reference)
    (None, 0.0, False, False),      # pure mask select (passthrough)
    (None, 0.0, True, False),       # quantize only
    (0.5, 0.0, False, False),       # clip only
    (0.5, 1.1, False, False),       # clip + DP noise
    (0.5, 1.1, True, False),        # the full DP + int8 wire
    (0.7, 0.0, False, True),        # delta-vs-reference clip
    (0.7, 0.9, True, True),         # reference + noise + quantize
]


def _run_upload(x, mask, keys, refrow, clip, nm, quant):
    got = ops.wire_upload(
        x, mask, keys=keys if nm > 0 else None, reference=refrow,
        clip_norm=clip, noise_multiplier=nm, quantize=quant)
    oracle = jax.jit(functools.partial(
        ref.wire_upload_ref, clip_norm=clip, noise_multiplier=nm,
        quantize=quant))
    want = oracle(x, mask=mask, keys=keys if nm > 0 else None,
                  reference=refrow)
    _exact(got, want)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("config", UPLOAD_CONFIGS)
@pytest.mark.parametrize("pattern", ["all", "none", "random"])
def test_upload_matches_oracle(shape, config, pattern):
    J, P = shape
    clip, nm, quant, use_ref = config
    x = _mat((J, P), salt=J * 1000 + P)
    mask = _mask(J, pattern, salt=J)
    keys = _keys(J, salt=P)
    refrow = 0.3 * _mat((P,), salt=P + 5) if use_ref else None
    _run_upload(x, mask, keys, refrow, clip, nm, quant)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_upload_input_dtypes(dtype):
    """Inputs upcast to f32 at the kernel edge, like the oracle."""
    x = _mat((5, 33), dtype=dtype)
    mask = _mask(5, "random")
    got = ops.wire_upload(x, mask, clip_norm=0.5, quantize=True)
    oracle = jax.jit(functools.partial(
        ref.wire_upload_ref, clip_norm=0.5, quantize=True))
    _exact(got, oracle(x, mask=mask))


def test_upload_block_rows_invariance():
    """Different row tilings of the same input agree bitwise (each row's
    pipeline is independent of which block it lands in)."""
    x = _mat((12, 96))
    mask = _mask(12, "random")
    keys = _keys(12)
    outs = [ops.wire_upload(x, mask, keys=keys, clip_norm=0.4,
                            noise_multiplier=1.0, quantize=True,
                            block_rows=br) for br in (1, 3, 12)]
    _exact(outs[0], outs[1])
    _exact(outs[0], outs[2])


def test_upload_noise_requires_clip_and_keys():
    x = _mat((3, 4))
    mask = _mask(3, "all")
    with pytest.raises(ValueError):
        ops.wire_upload(x, mask, noise_multiplier=1.0, clip_norm=None)
    with pytest.raises(ValueError):
        ops.wire_upload(x, mask, noise_multiplier=1.0, clip_norm=1.0,
                        keys=None)


class TestPrivacyStreamBitExact:
    """The kernel's in-row noise is the SAME stream PrivacyPolicy draws:
    fold the policy's upload key per silo, and the fused row equals the
    policy's privatize of that row — bit for bit, same round key."""

    def _policy_rows(self, pol, x, round_key, t):
        J = x.shape[0]
        priv = jax.jit(lambda v, k: pol.privatize(v, k))
        rows = [priv(x[j], pol.upload_key(round_key, t, j))
                for j in range(J)]
        return jnp.stack(rows)

    @pytest.mark.parametrize("t", [0, 3])
    @pytest.mark.parametrize("shape", [(1, 5), (4, 37), (7, 129)])
    def test_stream_matches_policy(self, shape, t):
        J, P = shape
        pol = PrivacyPolicy(clip_norm=0.7, noise_multiplier=1.3)
        round_key = jax.random.PRNGKey(123)
        x = _mat((J, P), salt=77)
        keys = jax.vmap(
            lambda s: jax.random.fold_in(pol.upload_key(round_key, t, s), 0)
        )(jnp.arange(J))
        got = ops.wire_upload(
            x, jnp.ones((J,), jnp.float32), keys=keys,
            clip_norm=pol.clip_norm, noise_multiplier=pol.noise_multiplier)
        want = self._policy_rows(pol, x, round_key, t)
        _exact(got, want)

    def test_different_rounds_different_noise(self):
        pol = PrivacyPolicy(clip_norm=0.7, noise_multiplier=1.3)
        x = _mat((3, 16))
        outs = []
        for rk in (jax.random.PRNGKey(0), jax.random.PRNGKey(1)):
            keys = jax.vmap(
                lambda s: jax.random.fold_in(pol.upload_key(rk, 0, s), 0)
            )(jnp.arange(3))
            outs.append(ops.wire_upload(
                x, jnp.ones((3,)), keys=keys, clip_norm=0.7,
                noise_multiplier=1.3))
        assert not np.array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


# ---------------------------------------------------------------------------
# fused combine: masked / weighted (trimmed) mean + in-kernel dequant
# ---------------------------------------------------------------------------

WEIGHT_PATTERNS = ["ones", "binary", "fractional", "subunit", "zero"]


def _weights(J, pattern, salt=0):
    k = jax.random.fold_in(KEY, 300 + salt)
    if pattern == "ones":
        return jnp.ones((J,), jnp.float32)
    if pattern == "binary":
        return jax.random.bernoulli(k, 0.6, (J,)).astype(jnp.float32)
    if pattern == "fractional":
        return jax.random.uniform(k, (J,), jnp.float32, 0.0, 1.0)
    if pattern == "subunit":  # async decayed weights summing below 1
        return jax.random.uniform(k, (J,), jnp.float32, 0.0, 1.0) / (2.0 * J)
    return jnp.zeros((J,), jnp.float32)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("pattern", WEIGHT_PATTERNS)
@pytest.mark.parametrize("trim", [None, 0.1, 0.25, 0.49])
def test_combine_matches_oracle_and_aggregator(shape, pattern, trim):
    J, P = shape
    x = _mat((J, P), salt=J * 31 + P)
    w = _weights(J, pattern, salt=J + P)
    got = ops.wire_combine(x, w, trim_frac=trim)
    if trim is None:
        want = jax.jit(ref.masked_weighted_mean_ref)(x, w)
        agg = MeanAggregator()
    else:
        want = jax.jit(functools.partial(
            ref.masked_trimmed_mean_ref, trim_frac=trim))(x, w)
        agg = TrimmedMeanAggregator(trim_frac=trim)
    _exact(got, want)
    live = jax.jit(agg.combine)(x, w)
    _exact(got, live)


@pytest.mark.parametrize("trim", [None, 0.2])
def test_combine_int8_dequant_in_kernel(trim):
    """scales= fuses dequant into the same pass: equals dequantizing to a
    materialized f32 matrix first."""
    y = 3.0 * _mat((6, 130))
    scale = jnp.max(jnp.abs(y), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(y / scale[:, None]), -127, 127).astype(jnp.int8)
    w = _weights(6, "fractional")
    got = ops.wire_combine(q, w, scales=scale, trim_frac=trim)
    dense = jax.jit(ref.int8_rows_dequant_ref)(q, scale)
    want = ops.wire_combine(dense, w, trim_frac=trim)
    _exact(got, want)


def test_combine_block_cols_invariance():
    x = _mat((5, 120))
    w = _weights(5, "fractional")
    outs = [ops.wire_combine(x, w, trim_frac=0.2, block_cols=bc)
            for bc in (1, 8, 120)]
    _exact(outs[0], outs[1])
    _exact(outs[0], outs[2])


def test_combine_scales_require_int8():
    with pytest.raises(ValueError):
        ops.wire_combine(_mat((3, 4)), jnp.ones((3,)),
                         scales=jnp.ones((3,)))


# ---------------------------------------------------------------------------
# fused Newton–Schulz sqrt
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [1, 2, 3, 8, 16])
@pytest.mark.parametrize("iters", [5, 25])
def test_sqrtm_matches_core_and_ref(d, iters):
    a = _mat((d, d), salt=d)
    mat = a @ a.T + 0.1 * jnp.eye(d)
    got = ops.sqrtm_ns(mat, num_iters=iters)
    core = jax.jit(functools.partial(
        sqrtm_newton_schulz, num_iters=iters))(mat)
    oracle = jax.jit(functools.partial(
        ref.newton_schulz_sqrtm_ref, num_iters=iters))(mat)
    _exact(got, core)
    _exact(got, oracle)


def test_sqrtm_is_a_sqrt():
    a = _mat((6, 6), salt=99)
    mat = a @ a.T + 0.5 * jnp.eye(6)
    s = ops.sqrtm_ns(mat, num_iters=30)
    np.testing.assert_allclose(np.asarray(s @ s), np.asarray(mat),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# property sweeps (hypothesis when present, fixed seeded grid otherwise)
# ---------------------------------------------------------------------------

def _check_random_case(J, P, trim_i, pattern_i):
    x = _mat((J, P), salt=J * 7919 + P)
    trim = (None, 0.1, 0.3)[trim_i]
    pattern = WEIGHT_PATTERNS[pattern_i]
    w = _weights(J, pattern, salt=J ^ P)
    got = ops.wire_combine(x, w, trim_frac=trim)
    if trim is None:
        want = jax.jit(ref.masked_weighted_mean_ref)(x, w)
    else:
        want = jax.jit(functools.partial(
            ref.masked_trimmed_mean_ref, trim_frac=trim))(x, w)
    _exact(got, want)
    mask = (w > 0).astype(jnp.float32)
    up = ops.wire_upload(x, mask, keys=_keys(J, salt=P),
                         clip_norm=0.6, noise_multiplier=0.8, quantize=True)
    oracle = jax.jit(functools.partial(
        ref.wire_upload_ref, clip_norm=0.6, noise_multiplier=0.8,
        quantize=True))
    _exact(up, oracle(x, mask=mask, keys=_keys(J, salt=P)))


if HAVE_HYPOTHESIS:
    @given(J=st.integers(1, 17), P=st.integers(1, 300),
           trim_i=st.integers(0, 2), pattern_i=st.integers(0, 4))
    @settings(max_examples=25, deadline=None)
    def test_wire_kernels_property(J, P, trim_i, pattern_i):
        _check_random_case(J, P, trim_i, pattern_i)
else:
    _rng = np.random.default_rng(515151)
    _CASES = [(int(j), int(p), int(t), int(m)) for j, p, t, m in zip(
        _rng.integers(1, 18, 12), _rng.integers(1, 301, 12),
        _rng.integers(0, 3, 12), _rng.integers(0, 5, 12), strict=True)]

    @pytest.mark.parametrize("J,P,trim_i,pattern_i", _CASES)
    def test_wire_kernels_property(J, P, trim_i, pattern_i):
        _check_random_case(J, P, trim_i, pattern_i)


@pytest.mark.tpu_only
def test_wire_kernels_compile_to_mosaic():
    """The compiled (non-interpret) lowering agrees with interpret mode.

    Only meaningful on a real TPU backend — interpret mode IS the CPU
    execution path, so there is nothing to cross-check here off-TPU.
    (Note the Mosaic path would also need a hardware PRNG for the noise
    stage; this exercises the noiseless kernels only.)
    """
    x = _mat((8, 256))
    mask = _mask(8, "random")
    a = ops.wire_upload(x, mask, clip_norm=0.5, quantize=True,
                        interpret=False)
    b = ops.wire_upload(x, mask, clip_norm=0.5, quantize=True,
                        interpret=True)
    _exact(a, b)
