"""Tests for the compiled federated runtime (repro.federated).

Covers the two correctness anchors from the paper:
  * partition invariance (§3 Remark): one Server SFVI round applies exactly
    the centralized gradient of ``SFVIProblem.centralized_objective``;
  * SFVI-Avg degenerates to SFVI at K=1 (§3.2): with SGD, equal silo
    sizes and parameter-space averaging the round maps are identical.
plus the aggregation/compression/scheduling plumbing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ConditionalGaussian,
    DiagGaussian,
    SFVIProblem,
    StructuredModel,
)
from repro.federated import (
    Int8Compressor,
    MeanAggregator,
    NoCompression,
    RoundScheduler,
    Server,
    TrimmedMeanAggregator,
    global_eps,
    silo_eps,
)
from repro.optim.adam import adam
from repro.optim.sgd import sgd


def _hier_problem(dG=3, dL=2, use_coupling=False):
    def log_prior_global(theta, zg):
        return -0.5 * jnp.sum((zg - theta["m"]) ** 2)

    def log_local(theta, zg, zl, data):
        lp = -0.5 * jnp.sum((zl - jnp.mean(zg)) ** 2)
        ll = -0.5 * jnp.sum((data["y"] - zl[None, :]) ** 2) * jnp.exp(theta["lt"])
        return lp + ll

    model = StructuredModel(
        global_dim=dG, local_dim=dL,
        log_prior_global=log_prior_global, log_local=log_local,
    )
    return SFVIProblem(
        model, DiagGaussian(dG), ConditionalGaussian(dL, dG, use_coupling=use_coupling)
    )


def _global_only_problem(dG=3):
    model = StructuredModel(
        global_dim=dG, local_dim=0,
        log_prior_global=lambda th, zg: -0.5 * jnp.sum((zg - th["m"]) ** 2),
        log_local=lambda th, zg, zl, d: -0.5 * jnp.sum((d["y"] - zg[None, :]) ** 2),
    )
    return SFVIProblem(model, DiagGaussian(dG))


def _datas(key, J, n, d):
    return [
        {"y": jax.random.normal(jax.random.fold_in(key, j), (n, d))}
        for j in range(J)
    ]


def _flat(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((0,))
    return jnp.concatenate([jnp.ravel(x) for x in leaves])


class TestPartitionInvariance:
    @pytest.mark.parametrize("J", [1, 3, 5])
    def test_server_round_matches_centralized_gradient(self, J):
        """One SFVI round with SGD(lr) moves (θ, η_G) by exactly
        lr · ∇ of the centralized single-graph objective."""
        lr = 0.05
        prob = _hier_problem()
        theta = {"m": jnp.asarray(0.3), "lt": jnp.asarray(-0.5)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(1), mu_scale=0.5)
        datas = _datas(jax.random.PRNGKey(2), J, n=4, d=2)

        srv = Server(prob, datas, theta, eta_G,
                     server_opt=sgd(lr), local_opt=sgd(lr), seed=7)
        eta_L0 = jax.tree_util.tree_map(jnp.copy, srv.eta_L)
        srv.run(1, algorithm="sfvi", local_steps=1)

        # Replay the exact shared-randomness draws of round 0, step 0.
        round_key = jax.random.fold_in(jax.random.PRNGKey(7), 0)
        eps_G = global_eps(prob, round_key, 0)
        eps_L = [silo_eps(prob, round_key, 0, j) for j in range(J)]
        etas_L = [jax.tree_util.tree_map(lambda x: x[j], eta_L0) for j in range(J)]

        g_th, g_eta = jax.grad(
            lambda th, eg: prob.centralized_objective(
                th, eg, etas_L, eps_G, eps_L, datas),
            argnums=(0, 1),
        )(theta, eta_G)

        np.testing.assert_allclose(
            _flat(srv.theta), _flat(theta) + lr * _flat(g_th), rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(
            _flat(srv.eta_G), _flat(eta_G) + lr * _flat(g_eta), rtol=2e-4, atol=2e-5)

    def test_elbo_improves_with_adam(self):
        prob = _hier_problem()
        theta = {"m": jnp.asarray(0.0), "lt": jnp.asarray(0.0)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(1))
        srv = Server(prob, _datas(jax.random.PRNGKey(2), 4, 6, 2), theta, eta_G,
                     server_opt=adam(2e-2), local_opt=adam(2e-2))
        h = srv.run(30, algorithm="sfvi", local_steps=2)
        assert h["elbo"][-1] > h["elbo"][0]


class TestAvgEqualsSfviAtK1:
    def test_full_state_equality_global_only(self):
        """No local latents: the K=1 SFVI-Avg round map IS the SFVI round
        map (SGD, equal N_j, parameter-space η_G merge)."""
        lr = 0.03
        prob = _global_only_problem()
        theta = {"m": jnp.asarray(0.2)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(3), mu_scale=0.4)
        datas = _datas(jax.random.PRNGKey(4), 4, n=5, d=3)

        kw = dict(server_opt=sgd(lr), eta_mode="param", seed=11)
        a = Server(prob, datas, theta, eta_G, **kw)
        b = Server(prob, datas, theta, eta_G, **kw)
        a.run(3, algorithm="sfvi", local_steps=1)
        b.run(3, algorithm="sfvi_avg", local_steps=1)

        np.testing.assert_allclose(_flat(a.theta), _flat(b.theta), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(_flat(a.eta_G), _flat(b.eta_G), rtol=1e-5, atol=1e-6)

    def test_server_state_equality_with_locals(self):
        """With local latents, (θ, η_G) still agree after one K=1 round:
        mean_j[∇(L̂_0 + (N/N_j) L̂_j)] = ∇L̂_0 + Σ_j ∇L̂_j for equal N_j."""
        lr = 0.03
        prob = _hier_problem()
        theta = {"m": jnp.asarray(0.1), "lt": jnp.asarray(-0.2)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(5), mu_scale=0.4)
        datas = _datas(jax.random.PRNGKey(6), 3, n=4, d=2)

        kw = dict(server_opt=sgd(lr), local_opt=sgd(lr), eta_mode="param", seed=13)
        a = Server(prob, datas, theta, eta_G, **kw)
        b = Server(prob, datas, theta, eta_G, **kw)
        a.run(1, algorithm="sfvi", local_steps=1)
        b.run(1, algorithm="sfvi_avg", local_steps=1)

        np.testing.assert_allclose(_flat(a.theta), _flat(b.theta), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(_flat(a.eta_G), _flat(b.eta_G), rtol=1e-5, atol=1e-6)

    def test_avg_improves_elbo(self):
        prob = _hier_problem()
        theta = {"m": jnp.asarray(0.0), "lt": jnp.asarray(0.0)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(1))
        srv = Server(prob, _datas(jax.random.PRNGKey(2), 4, 6, 2), theta, eta_G,
                     server_opt=adam(2e-2), local_opt=adam(2e-2))
        h = srv.run(10, algorithm="sfvi_avg", local_steps=8)
        assert h["elbo"][-1] > h["elbo"][0]


class TestAggregation:
    def test_mean_respects_mask(self):
        stacked = {"g": jnp.asarray([[1.0], [3.0], [100.0]])}
        mask = jnp.asarray([1.0, 1.0, 0.0])
        out = MeanAggregator().combine(stacked, mask)
        np.testing.assert_allclose(out["g"], [2.0])

    def test_trimmed_mean_drops_outlier(self):
        stacked = {"g": jnp.asarray([[1.0], [2.0], [3.0], [1000.0]])}
        mask = jnp.ones((4,))
        out = TrimmedMeanAggregator(trim_frac=0.25).combine(stacked, mask)
        np.testing.assert_allclose(out["g"], [2.5])  # drops 1.0 and 1000.0

    def test_trimmed_mean_excludes_inactive(self):
        stacked = {"g": jnp.asarray([[1.0], [2.0], [jnp.inf]])}
        mask = jnp.asarray([1.0, 1.0, 0.0])
        out = TrimmedMeanAggregator(trim_frac=0.0).combine(stacked, mask)
        np.testing.assert_allclose(out["g"], [1.5])


class TestCompression:
    def test_int8_roundtrip_and_bytes(self):
        tree = {"a": jnp.linspace(-1.0, 1.0, 256), "b": jnp.ones((8, 8))}
        comp = Int8Compressor()
        dec = comp.decode(comp.encode(tree))
        np.testing.assert_allclose(dec["a"], tree["a"], atol=1.0 / 127 + 1e-6)
        np.testing.assert_allclose(dec["b"], tree["b"], atol=1.0 / 127 + 1e-6)
        assert comp.wire_bytes(tree) < NoCompression().wire_bytes(tree)

    def test_int8_inside_server_still_converges(self):
        prob = _hier_problem()
        theta = {"m": jnp.asarray(0.0), "lt": jnp.asarray(0.0)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(1))
        srv = Server(prob, _datas(jax.random.PRNGKey(2), 4, 6, 2), theta, eta_G,
                     server_opt=adam(2e-2), local_opt=adam(2e-2),
                     compressor=Int8Compressor())
        h = srv.run(30, algorithm="sfvi", local_steps=2)
        assert h["elbo"][-1] > h["elbo"][0]
        raw = NoCompression().wire_bytes(srv.ship_template("sfvi"))
        assert srv.bytes_up_per_silo("sfvi") < raw


class TestScheduling:
    def test_masks_are_deterministic(self):
        s = RoundScheduler(8, participation=0.5, dropout=0.2, seed=3)
        np.testing.assert_array_equal(s.mask(5), s.mask(5))

    def test_participation_counts(self):
        s = RoundScheduler(8, participation=0.5, seed=0)
        m = np.asarray(s.masks(20))
        assert (m.sum(axis=1) == 4).all()

    def test_never_empty_round(self):
        s = RoundScheduler(4, participation=0.25, dropout=0.99, seed=0)
        m = np.asarray(s.masks(50))
        assert (m.sum(axis=1) >= 1).all()

    def test_zero_participation_draw_still_invites_one(self):
        """participation so low it rounds to zero silos: the scheduler
        must never draw an empty invitation (at least one silo is always
        invited), and the round must still run."""
        s = RoundScheduler(4, participation=0.01, seed=0)
        m = np.asarray(s.masks(20))
        assert (m.sum(axis=1) == 1).all()

        prob = _hier_problem()
        theta = {"m": jnp.asarray(0.0), "lt": jnp.asarray(0.0)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(1))
        srv = Server(prob, _datas(jax.random.PRNGKey(2), 4, 6, 2), theta, eta_G,
                     server_opt=adam(2e-2), local_opt=adam(2e-2))
        h = srv.run(3, algorithm="sfvi", local_steps=1,
                    scheduler=RoundScheduler(4, participation=0.01, seed=0))
        assert all(n == 1 for n in h["n_active"])
        assert all(np.isfinite(e) for e in h["elbo"])

    def test_all_silos_straggling_keeps_one_reporter(self):
        """dropout=1.0 (every invited silo straggles): the scheduler
        keeps the lowest-index invited silo so the round is never lost,
        only that silo's local state moves, and downloads are still
        billed for every invited straggler."""
        sched = RoundScheduler(4, dropout=1.0, seed=5)
        m = np.asarray(sched.masks(10))
        assert (m.sum(axis=1) == 1).all()
        assert (m[:, 0] == 1.0).all()  # lowest-index invitee survives

        prob = _hier_problem()
        theta = {"m": jnp.asarray(0.0), "lt": jnp.asarray(0.0)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(1))
        srv = Server(prob, _datas(jax.random.PRNGKey(2), 4, 6, 2), theta, eta_G,
                     server_opt=adam(2e-2), local_opt=adam(2e-2))
        eta_L0 = jax.tree_util.tree_map(jnp.copy, srv.eta_L)
        h = srv.run(2, algorithm="sfvi", local_steps=1, scheduler=sched)
        assert all(n == 1 for n in h["n_active"])
        # Frozen stragglers: silos 1..3 kept their exact η_L.
        for j in range(1, 4):
            for a, b in zip(jax.tree_util.tree_leaves(eta_L0),
                            jax.tree_util.tree_leaves(srv.eta_L), strict=True):
                np.testing.assert_array_equal(np.asarray(a[j]), np.asarray(b[j]))
        # All 4 invited silos received the broadcast each round.
        assert h["bytes_down"][0] == 4 * srv.bytes_down_per_silo()
        assert h["bytes_up"][0] == 1 * srv.bytes_up_per_silo("sfvi")

    def test_partial_participation_round_runs(self):
        prob = _hier_problem()
        theta = {"m": jnp.asarray(0.0), "lt": jnp.asarray(0.0)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(1))
        srv = Server(prob, _datas(jax.random.PRNGKey(2), 4, 6, 2), theta, eta_G,
                     server_opt=adam(2e-2), local_opt=adam(2e-2))
        h = srv.run(10, algorithm="sfvi", local_steps=1,
                    scheduler=RoundScheduler(4, participation=0.5, seed=1))
        assert all(n == 2 for n in h["n_active"])
        assert srv.comm.bytes_up < 10 * 4 * srv.bytes_up_per_silo("sfvi") + 1


class TestCommAccounting:
    def test_sfvi_pays_per_step_avg_pays_per_round(self):
        prob = _hier_problem()
        theta = {"m": jnp.asarray(0.0), "lt": jnp.asarray(0.0)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(1))
        K = 5
        a = Server(prob, _datas(jax.random.PRNGKey(2), 4, 6, 2), theta, eta_G,
                   server_opt=adam(2e-2), local_opt=adam(2e-2))
        b = Server(prob, _datas(jax.random.PRNGKey(2), 4, 6, 2), theta, eta_G,
                   server_opt=adam(2e-2), local_opt=adam(2e-2))
        a.run(2, algorithm="sfvi", local_steps=K)
        b.run(2, algorithm="sfvi_avg", local_steps=K)
        assert a.comm.per_round == K * b.comm.per_round
        assert b.comm.total < a.comm.total
