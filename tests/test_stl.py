"""Tests for the STL gradient estimator (paper §2, eq. 6; Roeder et al. 2017)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DiagGaussian, elbo_objective, stl_objective


def _conjugate_posterior():
    """y ~ N(z, 1), z ~ N(0,1), observed y=1.2 -> posterior N(0.6, 0.5)."""
    y = 1.2

    def log_joint(z):
        return -0.5 * jnp.sum(z**2) - 0.5 * jnp.sum((y - z) ** 2)

    post_mu = jnp.array([y / 2.0])
    post_sigma = jnp.array([jnp.sqrt(0.5)])
    return log_joint, post_mu, post_sigma


class TestSTL:
    def test_stl_gradient_is_zero_at_exact_posterior(self):
        """The defining STL property: zero-variance (identically zero)
        gradient when q equals the true posterior — for ANY eps."""
        log_joint, mu, sigma = _conjugate_posterior()
        fam = DiagGaussian(1)
        params = fam.from_moments(mu, sigma)
        for seed in range(5):
            eps = jax.random.normal(jax.random.PRNGKey(seed), (1,))
            g = jax.grad(lambda p: stl_objective(log_joint, fam, p, eps))(params)
            for leaf in jax.tree_util.tree_leaves(g):
                np.testing.assert_allclose(leaf, 0.0, atol=1e-6)

    def test_plain_estimator_is_not_zero_at_posterior(self):
        """The total-derivative estimator retains per-sample noise at the optimum
        (its *expectation* is zero but individual samples are not) — this is
        exactly why the paper uses STL."""
        log_joint, mu, sigma = _conjugate_posterior()
        fam = DiagGaussian(1)
        params = fam.from_moments(mu, sigma)
        eps = jax.random.normal(jax.random.PRNGKey(0), (1,))
        g = jax.grad(lambda p: elbo_objective(log_joint, fam, p, eps))(params)
        norm = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
        assert norm > 1e-4

    def test_stl_unbiasedness(self):
        """Away from the optimum, STL and plain estimators agree in expectation."""
        log_joint, _, _ = _conjugate_posterior()
        fam = DiagGaussian(1)
        params = {"mu": jnp.array([0.1]), "log_sigma": jnp.array([-0.3])}
        n = 200_000
        eps = jax.random.normal(jax.random.PRNGKey(1), (n, 1))
        g_stl = jax.vmap(
            lambda e: jax.grad(lambda p: stl_objective(log_joint, fam, p, e))(params)
        )(eps)
        g_tot = jax.vmap(
            lambda e: jax.grad(lambda p: elbo_objective(log_joint, fam, p, e))(params)
        )(eps)
        for k in params:
            np.testing.assert_allclose(
                jnp.mean(g_stl[k]), jnp.mean(g_tot[k]), atol=6e-3
            )

    def test_stl_lower_variance_near_optimum(self):
        log_joint, mu, sigma = _conjugate_posterior()
        fam = DiagGaussian(1)
        params = fam.from_moments(mu + 0.02, sigma * 1.02)
        n = 20_000
        eps = jax.random.normal(jax.random.PRNGKey(2), (n, 1))
        g_stl = jax.vmap(
            lambda e: jax.grad(lambda p: stl_objective(log_joint, fam, p, e))(params)
        )(eps)
        g_tot = jax.vmap(
            lambda e: jax.grad(lambda p: elbo_objective(log_joint, fam, p, e))(params)
        )(eps)
        var_stl = sum(float(jnp.var(g_stl[k])) for k in params)
        var_tot = sum(float(jnp.var(g_tot[k])) for k in params)
        assert var_stl < var_tot
