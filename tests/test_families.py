"""Unit tests for the variational families (paper §3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.families import (
    BatchedDiagGaussian,
    CholeskyGaussian,
    ConditionalGaussian,
    DiagGaussian,
)


def _mc_moments(sample_fn, dim, n=200_000, seed=0):
    eps = jax.random.normal(jax.random.PRNGKey(seed), (n, dim))
    zs = jax.vmap(sample_fn)(eps)
    return jnp.mean(zs, 0), jnp.cov(zs.T)


class TestDiagGaussian:
    def test_sample_matches_moments(self):
        fam = DiagGaussian(3)
        params = {"mu": jnp.array([1.0, -2.0, 0.5]), "log_sigma": jnp.log(jnp.array([0.5, 1.0, 2.0]))}
        mean, cov = _mc_moments(lambda e: fam.sample(params, e), 3)
        np.testing.assert_allclose(mean, params["mu"], atol=0.02)
        np.testing.assert_allclose(jnp.diag(cov), jnp.exp(params["log_sigma"]) ** 2, rtol=0.05)

    def test_log_prob_matches_manual(self):
        fam = DiagGaussian(4)
        params = fam.init(jax.random.PRNGKey(0))
        z = jax.random.normal(jax.random.PRNGKey(1), (4,))
        sigma = jnp.exp(params["log_sigma"])
        manual = jnp.sum(
            -0.5 * ((z - params["mu"]) / sigma) ** 2
            - jnp.log(sigma)
            - 0.5 * jnp.log(2 * jnp.pi)
        )
        np.testing.assert_allclose(fam.log_prob(params, z), manual, rtol=1e-6)

    def test_entropy_is_expected_neg_log_prob(self):
        fam = DiagGaussian(3)
        params = fam.init(jax.random.PRNGKey(0), log_sigma_init=0.3)
        eps = jax.random.normal(jax.random.PRNGKey(2), (100_000, 3))
        lps = jax.vmap(lambda e: fam.log_prob(params, fam.sample(params, e)))(eps)
        np.testing.assert_allclose(-jnp.mean(lps), fam.entropy(params), rtol=1e-2)

    def test_moments_roundtrip(self):
        fam = DiagGaussian(5)
        params = fam.init(jax.random.PRNGKey(3))
        mu, sigma = fam.to_moments(params)
        back = fam.from_moments(mu, sigma)
        for k in params:
            np.testing.assert_allclose(params[k], back[k], rtol=1e-6)


class TestCholeskyGaussian:
    def test_covariance_matches_samples(self):
        fam = CholeskyGaussian(3)
        key = jax.random.PRNGKey(0)
        params = fam.init(key, log_sigma_init=-0.5)
        params["L_packed"] = jnp.array([0.7, -0.3, 0.4])
        mean, cov = _mc_moments(lambda e: fam.sample(params, e), 3, n=400_000)
        np.testing.assert_allclose(mean, params["mu"], atol=0.02)
        np.testing.assert_allclose(cov, fam.covariance(params), atol=0.02)

    def test_log_prob_normalized_consistency(self):
        """log_prob at a sample equals the analytic MVN density."""
        fam = CholeskyGaussian(4)
        params = fam.init(jax.random.PRNGKey(1))
        params["L_packed"] = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (6,))
        z = fam.sample(params, jax.random.normal(jax.random.PRNGKey(3), (4,)))
        cov = fam.covariance(params)
        resid = z - params["mu"]
        manual = (
            -0.5 * resid @ jnp.linalg.solve(cov, resid)
            - 0.5 * jnp.linalg.slogdet(cov)[1]
            - 2.0 * jnp.log(2 * jnp.pi)
        )
        np.testing.assert_allclose(fam.log_prob(params, z), manual, rtol=1e-5)

    def test_from_moments_roundtrip(self):
        fam = CholeskyGaussian(3)
        params = fam.init(jax.random.PRNGKey(4))
        params["L_packed"] = jnp.array([0.5, -0.2, 0.1])
        cov = fam.covariance(params)
        back = fam.from_moments(params["mu"], cov)
        np.testing.assert_allclose(fam.covariance(back), cov, rtol=1e-5, atol=1e-7)

    def test_dim1_edge_case(self):
        fam = CholeskyGaussian(1)
        params = fam.init(jax.random.PRNGKey(5))
        z = fam.sample(params, jnp.array([0.3]))
        assert jnp.isfinite(fam.log_prob(params, z))


class TestConditionalGaussian:
    def test_coupling_shifts_conditional_mean(self):
        fam = ConditionalGaussian(2, 3, use_coupling=True)
        params = fam.init(jax.random.PRNGKey(0))
        params["C"] = jnp.ones((2, 3))
        mu_G = jnp.zeros(3)
        z_G = jnp.array([1.0, 0.0, -1.0])
        eps = jnp.zeros(2)
        z = fam.sample(params, z_G, mu_G, eps)
        np.testing.assert_allclose(z, params["mu_bar"] + jnp.sum(z_G), rtol=1e-6)

    def test_joint_covariance_structure(self):
        """Cov(Z_G, Z_L) = Σ_GG C_jᵀ (paper §3.1)."""
        dG, dL = 2, 2
        gfam = DiagGaussian(dG)
        lfam = ConditionalGaussian(dL, dG, use_coupling=True)
        gp = {"mu": jnp.zeros(dG), "log_sigma": jnp.log(jnp.array([1.0, 2.0]))}
        lp = lfam.init(jax.random.PRNGKey(1))
        lp["C"] = jnp.array([[0.5, -0.3], [0.2, 0.8]])
        n = 400_000
        epsG = jax.random.normal(jax.random.PRNGKey(2), (n, dG))
        epsL = jax.random.normal(jax.random.PRNGKey(3), (n, dL))
        zG = jax.vmap(lambda e: gfam.sample(gp, e))(epsG)
        zL = jax.vmap(lambda zg, e: lfam.sample(lp, zg, gp["mu"], e))(zG, epsL)
        sigma_gg = jnp.diag(jnp.exp(gp["log_sigma"]) ** 2)
        expected_cross = sigma_gg @ lp["C"].T
        full = jnp.cov(jnp.concatenate([zG, zL], 1).T)
        np.testing.assert_allclose(full[:dG, dG:], expected_cross, atol=0.03)

    def test_log_prob_with_chol(self):
        fam = ConditionalGaussian(3, 2, use_coupling=True, use_chol=True)
        params = fam.init(jax.random.PRNGKey(0))
        params["L_packed"] = jnp.array([0.4, -0.1, 0.6])
        z_G, mu_G = jnp.array([0.5, -0.5]), jnp.zeros(2)
        eps = jax.random.normal(jax.random.PRNGKey(1), (3,))
        z = fam.sample(params, z_G, mu_G, eps)
        # Reconstruct eps via log_prob internals: density at the sample should
        # equal the standard-normal density of eps minus the log-det.
        lp = fam.log_prob(params, z, z_G, mu_G)
        manual = (
            -0.5 * jnp.sum(eps**2)
            - jnp.sum(params["log_sigma"])
            - 1.5 * jnp.log(2 * jnp.pi)
        )
        np.testing.assert_allclose(lp, manual, rtol=1e-5)


class TestBatchedDiagGaussian:
    def test_shapes_and_logprob(self):
        fam = BatchedDiagGaussian(batch=4, dim=3)
        params = fam.init(jax.random.PRNGKey(0))
        eps = jax.random.normal(jax.random.PRNGKey(1), (4, 3))
        z = fam.sample(params, eps)
        assert z.shape == (4, 3)
        # Batched log-prob equals sum of per-row diag log-probs.
        row = DiagGaussian(3)
        total = sum(
            float(
                row.log_prob(
                    {"mu": params["mu"][i], "log_sigma": params["log_sigma"][i]}, z[i]
                )
            )
            for i in range(4)
        )
        np.testing.assert_allclose(float(fam.log_prob(params, z)), total, rtol=1e-5)
