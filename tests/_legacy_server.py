"""FROZEN pre-refactor Server snapshot — the bit-exactness oracle.

This file is a verbatim copy of ``repro/federated/runtime.py`` as of the
commit BEFORE the server-side update was factored into the pluggable
``ServerStrategy`` protocol (PR 7). The strategy-equivalence suite
(``tests/test_strategies.py``) runs the SAME configs through this legacy
``Server`` and the refactored registry-built one and asserts the
trajectories are bit-identical — including under DP + int8 + async and
across save/resume — on whatever machine the tests run, so the oracle
never suffers cross-platform float drift the way stored fixtures would.

Do not edit the algorithmic bodies here; the whole point is that they
stay what shipped. It only imports stable primitives (privacy policy,
aggregation, wire kernels, families, optimizers), none of which the
refactor touches semantically.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.barycenter import family_barycenter
from repro.core.family import eps_shape as family_eps_shape
from repro.core.family import supports_moments
from repro.core.flatten import TreeSpec
from repro.core.sfvi import SFVIProblem
from repro.federated.aggregation import (
    Int8Compressor,
    MeanAggregator,
    NoCompression,
    TrimmedMeanAggregator,
)
from repro.federated.metering import CommMeter, tree_bytes
from repro.kernels import wire as wire_kernels
from repro.federated.privacy import PrivacyPolicy, RdpAccountant
from repro.federated.scheduler import RoundScheduler
from repro.launch.mesh import make_silo_mesh
from repro.optim.base import GradientTransformation, apply_updates

PyTree = Any


# ---------------------------------------------------------------------------
# Shared-randomness helpers (exported: tests replay the exact draws)
# ---------------------------------------------------------------------------


def global_eps(problem: SFVIProblem, round_key: jnp.ndarray, t) -> jnp.ndarray:
    """ε_G for local step ``t`` of a round — identical on every silo."""
    return jax.random.normal(
        jax.random.fold_in(round_key, t),
        family_eps_shape(problem.global_family),
    )


def silo_eps(problem: SFVIProblem, round_key: jnp.ndarray, t, silo_id):
    """ε_{L_j} for local step ``t`` on silo ``silo_id`` (None if Z_L = ∅)."""
    if not problem.model.has_local:
        return None
    key = jax.random.fold_in(jax.random.fold_in(round_key, 100_003 + t), silo_id)
    return jax.random.normal(key, family_eps_shape(problem.local_family))


def stack_silos(datas: Sequence[PyTree]) -> PyTree:
    """Stack J per-silo data pytrees along a new leading silo axis.

    All silos must share leaf shapes (equal-sized shards — what the
    partitioners in ``repro.data.partition`` produce); ragged federations
    pad to the max and mask inside ``log_local``.
    """
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *datas)


def _neg(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x: -x, tree)


def _add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def _select(keep, new: PyTree, old: PyTree) -> PyTree:
    """Per-leaf ``where`` that preserves dtypes (masked silo-state update)."""
    return jax.tree_util.tree_map(lambda n, o: jnp.where(keep, n, o), new, old)


def _coalesced_all_gather(tree: PyTree, axis_name: str) -> PyTree:
    """Cross-silo gather as ONE ``all_gather`` per wire dtype.

    A naive per-leaf ``tree_map(all_gather)`` emits one collective per
    pytree leaf — more instructions (and collective launches) than the
    algorithm needs, and it makes the "one gather per exchange" claim of
    §3.2 unverifiable in the HLO. Instead: flatten every leaf of the
    (already encoded, already privatized) upload to ``(stack, size)``,
    concatenate per dtype into one contiguous buffer, gather that, and
    split back. Uncompressed float uploads produce exactly one
    ``all-gather`` instruction in the compiled round; int8 compression
    produces two (payload + scales), still independent of leaf count
    and of ``local_steps``.

    Leaves must share a leading stacked-silo axis (what the runtime's
    vmapped ``per_silo`` emits); the gather tiles along it.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree
    stack = leaves[0].shape[0]
    groups: Dict[Any, list] = {}
    for i, x in enumerate(leaves):
        groups.setdefault(jnp.dtype(x.dtype), []).append(i)
    out: list = [None] * len(leaves)
    for dt in sorted(groups, key=lambda d: d.name):
        idxs = groups[dt]
        flat = jnp.concatenate(
            [leaves[i].reshape(stack, -1) for i in idxs], axis=1
        )
        gathered = jax.lax.all_gather(flat, axis_name, axis=0, tiled=True)
        off = 0
        for i in idxs:
            size = int(np.prod(leaves[i].shape[1:], dtype=np.int64))
            piece = gathered[:, off : off + size]
            out[i] = piece.reshape((-1,) + leaves[i].shape[1:])
            off += size
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Fused-wire plumbing (wire="fused"): the upload pipeline and the server
# reduction run as the Pallas kernels of repro.kernels.wire, applied to the
# stacked (J, P) block AFTER the per-silo vmap instead of leaf-by-leaf
# inside it. Semantics match the flat path exactly (same op sequence, same
# PRNG stream); only the pass structure changes.
# ---------------------------------------------------------------------------


def _fused_keys(privacy, round_key, t, sids):
    """(J, 2) per-row DP noise keys: fold_in(upload_key(rk, t, j), 0).

    The trailing fold_in(·, 0) is ``PrivacyPolicy.noise``'s per-leaf
    fold for the single flat leaf — precomputing it per row makes the
    in-kernel draw bit-identical to the policy's stream.
    """
    if privacy is None or privacy.noise_multiplier <= 0.0:
        return None
    return jax.vmap(
        lambda s: jax.random.fold_in(privacy.upload_key(round_key, t, s), 0)
    )(sids)


def _fused_ship(mat, mask_sh, keys, reference, privacy, comp, int8):
    """Privatize + mask + encode a stacked (J, P) block in one fused pass."""
    out = wire_kernels.fused_upload(
        mat,
        mask=mask_sh,
        keys=keys,
        reference=reference,
        clip_norm=None if privacy is None else privacy.clip_norm,
        noise_multiplier=0.0 if privacy is None else privacy.noise_multiplier,
        quantize=int8,
    )
    if int8:
        q, scales = out
        return {"q": q, "scale": scales}
    if type(comp) is NoCompression:
        return out
    # Custom codec: fall back to the per-silo encode on the fused output.
    return jax.vmap(comp.encode)(out)


def _fused_decode(enc, comp, int8):
    """Gathered fused wire -> dequantized (J, P) float32 matrix."""
    if int8:
        return enc["q"].astype(jnp.float32) * enc["scale"][:, None]
    if type(comp) is NoCompression:
        return enc
    return jax.vmap(comp.decode)(enc)


class LegacyServer:
    """Round-based federation driver over a compiled multi-silo graph.

    Owns the replicated server state (θ, η_G, server optimizer) and the
    silo-sharded state (stacked η_{L_j} and local optimizer states), and
    advances them one *round* at a time through a jitted ``shard_map``
    graph. ``run(algorithm="sfvi")`` synchronizes every local step;
    ``run(algorithm="sfvi_avg")`` runs ``local_steps`` local VI steps on
    the N/N_j-rescaled objective and aggregates parameters once per round
    (FedAvg for θ, Wasserstein barycenter — or parameter-space mean —
    for η_G).

    Args:
      problem: the :class:`~repro.core.sfvi.SFVIProblem` to optimize.
      datas: list of J per-silo data pytrees with equal leaf shapes.
      theta: initial model parameters θ (``{}`` for fully-Bayesian).
      eta_G: initial global variational parameters η_G.
      num_obs: per-silo observation counts N_j (default: leading dim of
        each silo's first data leaf) — drives SFVI-Avg's N/N_j rescale.
      server_opt: optimizer for (θ, η_G). Descent convention; the runtime
        flips signs to ascend the ELBO.
      local_opt: optimizer for each η_{L_j} (state is stacked per silo).
      aggregator: cross-silo combine rule (mean / trimmed mean / custom).
      compressor: silo→server wire codec (identity / int8 quantization).
      eta_mode: ``"barycenter"`` (paper §3.2 — any family exposing the
        ``to_moments``/``from_moments`` bridge: analytic for diag-form
        families, the in-graph Newton–Schulz fixed point for
        full-covariance ones) or ``"param"`` (FedAvg in parameter
        space) for SFVI-Avg's η_G merge.
      wire: silo→server wire layout. ``"flat"`` (default) packs each
        upload into ONE contiguous float32 vector
        (:class:`~repro.core.flatten.TreeSpec`), so DP clip+noise,
        compression, the cross-silo gather and the aggregator all
        operate on a single (J, P) matrix — fewer HLO ops per round and
        one int8 scale per silo instead of one per leaf. ``"fused"``
        keeps the flat layout but runs the upload pipeline (clip + DP
        noise + mask + int8 quantize) and the server reduction as the
        fused Pallas kernels of :mod:`repro.kernels.wire` — identical
        semantics (bit-exact without DP/compression; the DP noise
        stream is bit-identical by construction), fewer memory passes.
        ``"legacy"`` keeps the per-leaf pytree wire (benchmark/debug
        reference).
      privacy: optional :class:`~repro.federated.privacy.PrivacyPolicy`.
        When set, every silo upload is L2-clipped and Gaussian-noised
        *inside* the compiled round — before the compression hook and
        the ``all_gather``, so the wire carries already-privatized bytes
        (SFVI privatizes the gradient tree; SFVI-Avg the parameter delta
        from the round's public broadcast). The Server then owns an
        :class:`~repro.federated.privacy.RdpAccountant` composing every
        exchange; ``run`` reports cumulative ε per round.
      mesh: optional silo mesh (default ``make_silo_mesh(J)``).
      seed: base seed for the round key stream.
    """

    def __init__(
        self,
        problem: SFVIProblem,
        datas: Sequence[PyTree],
        theta: PyTree,
        eta_G: PyTree,
        *,
        num_obs: Optional[Sequence[int]] = None,
        server_opt: GradientTransformation,
        local_opt: Optional[GradientTransformation] = None,
        aggregator=None,
        compressor=None,
        eta_mode: str = "barycenter",
        wire: str = "flat",
        privacy: Optional[PrivacyPolicy] = None,
        mesh=None,
        seed: int = 0,
    ):
        self.problem = problem
        self.J = len(datas)
        self.aggregator = aggregator or MeanAggregator()
        self.compressor = compressor or NoCompression()
        self.privacy = privacy
        self.accountant = RdpAccountant() if privacy is not None else None
        self.mesh = mesh if mesh is not None else make_silo_mesh(self.J)
        # The stacked silo axis is padded up to a multiple of the mesh
        # size with dummy silos (copies of silo 0's data, permanently
        # masked out), so ANY J shards over every device — a prime J on
        # a 4-device mesh no longer collapses the federation onto one
        # device. All masks/weights entering the compiled round carry
        # zeros for the padded tail; the J-rescales below always use the
        # real J. On divisible meshes J_pad == J and nothing changes.
        n_dev = int(self.mesh.shape["silo"])
        self.J_pad = ((self.J + n_dev - 1) // n_dev) * n_dev
        datas = list(datas)
        self.data = stack_silos(datas + [datas[0]] * (self.J_pad - self.J))
        self.seed = seed
        self._server_opt = server_opt
        self._local_opt = local_opt
        self._has_local = problem.model.has_local
        if eta_mode not in ("barycenter", "param"):
            raise ValueError(f"unknown eta_mode {eta_mode!r}")
        if eta_mode == "barycenter" and not supports_moments(
            problem.global_family
        ):
            raise ValueError(
                "eta_mode='barycenter' needs a global family exposing "
                "to_moments/from_moments (DiagGaussian, CholeskyGaussian, "
                "LowRankGaussian, ...); pass eta_mode='param' for "
                f"{type(problem.global_family).__name__}"
            )
        self.eta_mode = eta_mode
        if wire not in ("flat", "fused", "legacy"):
            raise ValueError(
                f"unknown wire layout {wire!r} (flat/fused/legacy)")
        self.wire = wire

        if num_obs is None:
            num_obs = [
                int(jax.tree_util.tree_leaves(d)[0].shape[0])
                for d in datas[: self.J]
            ]
        num_obs = list(num_obs) + [num_obs[0]] * (self.J_pad - self.J)
        self.num_obs = np.asarray(num_obs, np.float32)

        if self._has_local:
            if local_opt is None:
                raise ValueError("local_opt is required when the model has Z_L")
            # Real silos draw the same keys regardless of padding (the
            # split width is J, not J_pad) so trajectories agree across
            # device counts; the padded rows reuse silo 0's init and are
            # frozen by their permanent zero mask.
            keys = jax.random.split(jax.random.PRNGKey(seed + 1), self.J)
            eta_L = jax.vmap(problem.local_family.init)(keys)
            eta_L = self.pad_silo_axis(eta_L)
            opt_L = jax.vmap(local_opt.init)(eta_L)
        else:
            eta_L, opt_L = {}, {}
        self.state: Dict[str, PyTree] = {
            "theta": theta,
            "eta_G": eta_G,
            "eta_L": eta_L,
            "opt_server": server_opt.init({"theta": theta, "eta_G": eta_G}),
            "opt_local": opt_L,
        }
        self.comm = CommMeter()
        self._round_fns: Dict[tuple, Callable] = {}

    # -- convenience accessors (mirror the host runtime's attributes) -------

    @property
    def theta(self) -> PyTree:
        """Current model parameters θ (replicated)."""
        return self.state["theta"]

    @property
    def eta_G(self) -> PyTree:
        """Current global variational parameters η_G (replicated)."""
        return self.state["eta_G"]

    @property
    def eta_L(self) -> PyTree:
        """Stacked per-silo variational parameters η_{L_j}.

        Leading axis is ``J_pad`` (= J rounded up to the mesh size);
        rows ``J:`` are permanently-masked padding — slice ``[:J]`` for
        the real federation.
        """
        return self.state["eta_L"]

    # -- silo-axis padding ---------------------------------------------------

    def pad_silo_axis(self, tree: PyTree) -> PyTree:
        """Pad a J-leading stacked tree to ``J_pad`` rows (tile row 0).

        Padded rows never influence the run: every mask/weight vector
        carries zeros for them, so their state stays frozen and their
        uploads are masked out of the aggregation.
        """
        pad = self.J_pad - self.J
        if pad == 0:
            return tree
        return jax.tree_util.tree_map(
            lambda x: jnp.concatenate(
                [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])], axis=0
            ),
            tree,
        )

    def _pad_mask(self, mask: jnp.ndarray) -> jnp.ndarray:
        """Extend a (J,) mask/weight vector with zeros for padded silos."""
        pad = self.J_pad - self.J
        if pad == 0:
            return mask
        return jnp.concatenate([mask, jnp.zeros((pad,), mask.dtype)])

    # -- wire accounting -----------------------------------------------------

    def ship_template(self, algorithm: str) -> PyTree:
        """Shape-only pytree of one silo's upload (pre-compression)."""
        if algorithm == "sfvi":
            return {"g_theta": self.state["theta"], "g_eta": self.state["eta_G"]}
        return {"theta": self.state["theta"], "eta_G": self.state["eta_G"]}

    def wire_spec(self, algorithm: str) -> TreeSpec:
        """The flat wire bijection of one upload (static; P = its dim)."""
        return TreeSpec.of(self.ship_template(algorithm))

    def bytes_up_per_silo(self, algorithm: str) -> int:
        """Post-compression upload bytes for one silo, one gather.

        On the flat wire the compressor sees ONE (P,) float32 vector —
        an int8 codec therefore pays a single 4-byte scale per silo
        instead of one per pytree leaf.
        """
        template = self.ship_template(algorithm)
        if self.wire in ("flat", "fused"):
            template = np.zeros((self.wire_spec(algorithm).dim,), np.float32)
        return self.compressor.wire_bytes(template)

    def bytes_down_per_silo(self) -> int:
        """Broadcast bytes: (θ, η_G) raw; the round key is ~0 and elided."""
        return NoCompression().wire_bytes(
            {"theta": self.state["theta"], "eta_G": self.state["eta_G"]}
        )

    def compiled_collective_bytes(
        self, algorithm: str = "sfvi", local_steps: int = 1
    ) -> Dict[str, float]:
        """Ring-traffic bytes per collective kind in the compiled round.

        Lowers the jitted round function and applies
        ``launch.roofline.collective_bytes`` to the optimized HLO. On a
        single-device mesh XLA elides the collectives entirely (all
        entries 0); run under a multi-device mesh (or the forced-host-
        device trick of ``launch/comm.py``) for real numbers.
        """
        from repro.launch.roofline import collective_bytes

        fn = self._get_round(algorithm, local_steps)
        mask_shape = ((local_steps, self.J_pad) if algorithm == "sfvi"
                      else (self.J_pad,))
        ones = jnp.ones(mask_shape, jnp.float32)
        args = (
            self.state,
            self.data,
            jax.random.PRNGKey(0),
            ones,
            ones,
        )
        return collective_bytes(fn.lower(*args).compile().as_text())

    def compiled_roofline(
        self, algorithm: str = "sfvi", local_steps: int = 1
    ) -> Dict[str, float]:
        """Roofline terms of the compiled round: FLOPs + bytes moved.

        Lowers the jitted round function and reads XLA's
        ``cost_analysis`` (per-partition FLOPs and HBM bytes accessed)
        plus ``launch.roofline.collective_bytes`` on the optimized HLO.
        The ``bytes_accessed`` term is what the fused wire kernels
        attack: fewer memory passes over the (J, P) matrix per round.
        """
        from repro.launch.roofline import collective_bytes

        fn = self._get_round(algorithm, local_steps)
        mask_shape = ((local_steps, self.J_pad) if algorithm == "sfvi"
                      else (self.J_pad,))
        ones = jnp.ones(mask_shape, jnp.float32)
        compiled = fn.lower(
            self.state, self.data, jax.random.PRNGKey(0), ones, ones
        ).compile()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # jax < 0.5 wraps it per-program
            ca = ca[0] if ca else {}
        return {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "collective_bytes": float(
                sum(collective_bytes(compiled.as_text()).values())),
        }

    def _fused_trim(self):
        """Fused-reduction mode for the configured aggregator.

        ``(None,)`` → fused weighted mean, ``(frac,)`` → fused trimmed
        mean, ``None`` → aggregator not expressible as a fused kernel
        (custom subclass): the fused wire falls back to
        ``aggregator.combine`` on the dequantized matrix.
        """
        if type(self.aggregator) is MeanAggregator:
            return (None,)
        if type(self.aggregator) is TrimmedMeanAggregator:
            return (float(self.aggregator.trim_frac),)
        return None

    # -- the compiled round --------------------------------------------------

    def _get_round(self, algorithm: str, local_steps: int) -> Callable:
        key = (algorithm, local_steps)
        if key not in self._round_fns:
            if algorithm == "sfvi":
                body = self._sfvi_body(local_steps)
            elif algorithm == "sfvi_avg":
                body = self._avg_body(local_steps)
            else:
                raise ValueError(f"unknown algorithm {algorithm!r}")
            sharded = shard_map(
                body,
                mesh=self.mesh,
                in_specs=(
                    P(), P(), P(),  # theta, eta_G, opt_server (replicated)
                    P("silo"), P("silo"),  # eta_L, opt_local
                    P("silo"), P("silo"), P("silo"),  # data, sids, n_j
                    # Participation mask rides ONCE, replicated; each block
                    # slices its silos' entries via sids. Passing it a
                    # second time with P("silo") made GSPMD reshard it with
                    # an extra 4-byte all-gather in the compiled round.
                    # ``weights`` are the aggregation weights (== mask on
                    # the sync path; staleness-decayed on the async path).
                    P(), P(), P(),  # full mask, full weights, round key
                ),
                out_specs=(P(), P(), P(), P("silo"), P("silo"), P()),
                check_rep=False,
            )

            def round_fn(state, data, round_key, mask, weights):
                sids = jnp.arange(self.J_pad, dtype=jnp.int32)
                n_j = jnp.asarray(self.num_obs)
                theta, eta_G, opt_server, eta_L, opt_L, elbos = sharded(
                    state["theta"], state["eta_G"], state["opt_server"],
                    state["eta_L"], state["opt_local"],
                    data, sids, n_j, mask, weights, round_key,
                )
                new_state = {
                    "theta": theta, "eta_G": eta_G, "eta_L": eta_L,
                    "opt_server": opt_server, "opt_local": opt_L,
                }
                return new_state, {"elbo": elbos}

            self._round_fns[key] = jax.jit(round_fn)
        return self._round_fns[key]

    def _sfvi_body(self, K: int) -> Callable:
        """Round = K synchronized steps: gather + server update every step."""
        problem, J = self.problem, self.J
        agg, comp = self.aggregator, self.compressor
        server_opt, local_opt = self._server_opt, self._local_opt
        has_local = self._has_local
        privacy = self.privacy
        # Flat wire: the whole upload is ONE (P,) f32 vector, so clip,
        # noise, quantization, the gather and the aggregation below all
        # see a single array per silo ((J, P) once stacked). The fused
        # wire keeps the same layout but runs those stages as the Pallas
        # kernels of repro.kernels.wire on the stacked block.
        wire = self.wire_spec("sfvi") if self.wire != "legacy" else None
        fused = self.wire == "fused"
        int8 = type(comp) is Int8Compressor
        trim = self._fused_trim()

        def body(theta, eta_G, opt_server, eta_L, opt_L,
                 data_sh, sids, n_j, masks_full, weights_full, round_key):
            # masks_full: (K, J) — SFVI samples participation PER EXCHANGE
            # (it synchronizes every step, so each gather is its own
            # subsampling event; this is what makes the accountant's
            # per-exchange amplification sound — one shared mask across
            # the K gathers would expose K correlated outputs per draw).
            # weights_full: (K, J) aggregation weights — identical to
            # masks_full on the sync path.
            del n_j  # SFVI needs no N/N_j rescale (likelihood_scale = 1)

            def sync_step(carry, step_xs):
                t, mask_full, w_full = step_xs
                mask_sh = mask_full[sids]  # this block's silos
                n_active = jnp.maximum(jnp.sum(mask_full), 1.0)
                theta, eta_G, opt_server, eta_L, opt_L = carry
                eps_G = global_eps(problem, round_key, t)

                def per_silo(eta_Lj, opt_Lj, data_j, sid, m_j):
                    el = eta_Lj if has_local else None
                    eps_L = silo_eps(problem, round_key, t, sid)
                    g_th, g_eta, g_loc, hatLj = problem.silo_grads(
                        theta, eta_G, el, eps_G, eps_L, data_j
                    )
                    if has_local:
                        upd, new_opt = local_opt.update(_neg(g_loc), opt_Lj, el)
                        eta_Lj = _select(m_j > 0.5, apply_updates(el, upd), el)
                        opt_Lj = _select(m_j > 0.5, new_opt, opt_Lj)
                    ship = {"g_theta": g_th, "g_eta": g_eta}
                    if wire is not None:
                        ship = wire.pack(ship)
                    if fused:
                        # Privatize/mask/quantize run as ONE fused pass
                        # over the stacked (J, P) block after the vmap.
                        return eta_Lj, opt_Lj, ship, hatLj * m_j
                    if privacy is not None:
                        # Clip + noise BEFORE compression and the gather:
                        # the wire never carries a raw silo gradient.
                        ship = privacy.privatize(
                            ship, privacy.upload_key(round_key, t, sid)
                        )
                    # Non-participating silos upload a data-independent
                    # zero tree (they "don't upload"; aggregation masks
                    # them anyway). This is what makes the accountant's
                    # subsampling amplification valid: an unsampled
                    # silo's data is absent from the wire, not merely
                    # down-weighted at the server.
                    ship = _select(
                        m_j > 0.5, ship,
                        jax.tree_util.tree_map(jnp.zeros_like, ship),
                    )
                    ship = comp.encode(ship)
                    return eta_Lj, opt_Lj, ship, hatLj * m_j

                eta_L, opt_L, enc, hatL = jax.vmap(per_silo)(
                    eta_L, opt_L, data_sh, sids, mask_sh
                )
                if fused:
                    enc = _fused_ship(
                        enc, mask_sh, _fused_keys(privacy, round_key, t, sids),
                        None, privacy, comp, int8)
                enc = _coalesced_all_gather(enc, "silo")
                hatL_sum = jax.lax.psum(jnp.sum(hatL), "silo")

                if fused and int8 and trim is not None:
                    # Dequantize inside the reduction kernel: the server
                    # never materializes the dequantized (J, P) matrix.
                    mean_g = wire_kernels.fused_combine(
                        enc["q"], w_full, scales=enc["scale"],
                        trim_frac=trim[0])
                elif fused:
                    mat = _fused_decode(enc, comp, int8)
                    mean_g = (wire_kernels.fused_combine(
                        mat, w_full, trim_frac=trim[0])
                        if trim is not None else agg.combine(mat, w_full))
                else:
                    shipped = jax.vmap(comp.decode)(enc)  # (J, P) | per leaf
                    mean_g = agg.combine(shipped, w_full)
                g_sum = jax.tree_util.tree_map(lambda x: x * float(J), mean_g)
                if wire is not None:
                    g_sum = wire.unpack(g_sum)
                g_th0, g_eta0, hatL0 = problem.server_grads(theta, eta_G, eps_G)
                g = {
                    "theta": _add(g_sum["g_theta"], g_th0),
                    "eta_G": _add(g_sum["g_eta"], g_eta0),
                }
                params = {"theta": theta, "eta_G": eta_G}
                updates, opt_server = server_opt.update(_neg(g), opt_server, params)
                merged = apply_updates(params, updates)
                elbo = hatL0 + (float(J) / n_active) * hatL_sum
                carry = (merged["theta"], merged["eta_G"], opt_server, eta_L, opt_L)
                return carry, elbo

            carry = (theta, eta_G, opt_server, eta_L, opt_L)
            carry, elbos = jax.lax.scan(
                sync_step, carry, (jnp.arange(K), masks_full, weights_full)
            )
            return (*carry, elbos)

        return body

    def _avg_body(self, K: int) -> Callable:
        """Round = K local VI steps per silo, ONE gather + parameter merge."""
        problem, J = self.problem, self.J
        agg, comp = self.aggregator, self.compressor
        server_opt, local_opt = self._server_opt, self._local_opt
        has_local = self._has_local
        eta_mode = self.eta_mode
        privacy = self.privacy
        wire = self.wire_spec("sfvi_avg") if self.wire != "legacy" else None
        fused = self.wire == "fused"
        int8 = type(comp) is Int8Compressor
        trim = self._fused_trim()
        # N = Σ_j N_j over the REAL federation — the padded tail repeats
        # silo 0's count purely to keep the dummy silos' per-silo scale
        # finite (their contribution is masked out regardless).
        total_obs = float(np.sum(self.num_obs[: self.J]))

        def body(theta, eta_G, opt_server, eta_L, opt_L,
                 data_sh, sids, n_j, mask_full, w_full, round_key):
            mask_sh = mask_full[sids]  # this block's silos
            n_active = jnp.maximum(jnp.sum(mask_full), 1.0)
            # The round's public broadcast in wire form: the DP delta
            # reference AND the data-independent upload of silos that
            # did not participate.
            broadcast = {"theta": theta, "eta_G": eta_G}
            if wire is not None:
                broadcast = wire.pack(broadcast)

            def per_silo(eta_Lj, opt_Lj, data_j, sid, m_j, n_obs_j):
                scale = total_obs / n_obs_j  # §3.2 point 2: N / N_j
                el0 = eta_Lj if has_local else None
                s_state = server_opt.init({"theta": theta, "eta_G": eta_G})

                def local_step(carry, t):
                    th, eg, el, s_st, l_st = carry
                    eps_G = global_eps(problem, round_key, t)
                    eps_L = silo_eps(problem, round_key, t, sid)

                    def objective(th_, eg_, el_):
                        val = problem.hat_L0(th_, eg_, eps_G)
                        return val + problem.hat_Lj(
                            th_, eg_, el_, eps_G, eps_L, data_j, scale
                        )

                    if has_local:
                        val, (g_th, g_eg, g_el) = jax.value_and_grad(
                            objective, argnums=(0, 1, 2)
                        )(th, eg, el)
                        upd_l, l_st = local_opt.update(_neg(g_el), l_st, el)
                        el = apply_updates(el, upd_l)
                    else:
                        val, (g_th, g_eg) = jax.value_and_grad(
                            lambda a, b: objective(a, b, None), argnums=(0, 1)
                        )(th, eg)
                    params = {"theta": th, "eta_G": eg}
                    upd_s, s_st = server_opt.update(
                        _neg({"theta": g_th, "eta_G": g_eg}), s_st, params
                    )
                    merged = apply_updates(params, upd_s)
                    return (merged["theta"], merged["eta_G"], el, s_st, l_st), val

                carry = (theta, eta_G, el0, s_state, opt_Lj)
                (th, eg, el, _, l_st), elbos = jax.lax.scan(
                    local_step, carry, jnp.arange(K)
                )
                if has_local:
                    eta_Lj = _select(m_j > 0.5, el, el0)
                    opt_Lj = _select(m_j > 0.5, l_st, opt_Lj)
                ship = {"theta": th, "eta_G": eg}
                if wire is not None:
                    ship = wire.pack(ship)
                if fused:
                    # Delta-clip/noise vs the broadcast, the broadcast
                    # fallback for non-participants, and quantization all
                    # run as ONE fused pass on the stacked block.
                    return eta_Lj, opt_Lj, ship, elbos * m_j
                if privacy is not None:
                    # Parameter upload: the private quantity is the delta
                    # from the round's broadcast (θ, η_G), which the server
                    # already knows. Clip + noise the delta, add it back —
                    # the wire format (flat vector or parameter pytree) is
                    # unchanged, and it is privatized before compression
                    # and the gather.
                    ship = privacy.privatize(
                        ship,
                        privacy.upload_key(round_key, 0, sid),
                        reference=broadcast,
                    )
                # Non-participating silos upload the round's public
                # broadcast — data-independent, so the subsampling
                # amplification in the accountant actually holds on the
                # wire (aggregation masks these rows regardless).
                ship = _select(m_j > 0.5, ship, broadcast)
                ship = comp.encode(ship)
                return eta_Lj, opt_Lj, ship, elbos * m_j

            eta_L, opt_L, enc, elbos = jax.vmap(per_silo)(
                eta_L, opt_L, data_sh, sids, mask_sh, n_j
            )
            if fused:
                enc = _fused_ship(
                    enc, mask_sh, _fused_keys(privacy, round_key, 0, sids),
                    broadcast, privacy, comp, int8)
            enc = _coalesced_all_gather(enc, "silo")
            elbo_t = jax.lax.psum(jnp.sum(elbos, axis=0), "silo") / n_active

            if fused:
                # The barycenter needs every silo's η_G anyway, so the
                # dequantized matrix is materialized here (unlike SFVI);
                # the reduction itself still runs as the fused kernel.
                shipped = _fused_decode(enc, comp, int8)
                vec = (wire_kernels.fused_combine(
                    shipped, w_full, trim_frac=trim[0])
                    if trim is not None else agg.combine(shipped, w_full))
                merged = wire.unpack(vec)
                eta_shipped = jax.vmap(lambda v: wire.unpack(v)["eta_G"])(
                    shipped)
            elif wire is not None:
                shipped = jax.vmap(comp.decode)(enc)  # (J, P)
                merged = wire.unpack(agg.combine(shipped, w_full))
                eta_shipped = jax.vmap(lambda v: wire.unpack(v)["eta_G"])(
                    shipped)
            else:
                shipped = jax.vmap(comp.decode)(enc)  # stacked pytree
                merged = {k: agg.combine(v, w_full)
                          for k, v in shipped.items()}
                eta_shipped = shipped["eta_G"]
            theta_new = merged["theta"]
            if eta_mode == "param":
                eta_new = merged["eta_G"]
            else:
                # W2 barycenter in moment space, generic over the
                # family's moment bridge: analytic (aggregator-
                # robustified) for diag-form families, the in-graph
                # Newton–Schulz fixed point for full-covariance ones
                # (the fused wire plugs in the fused-step kernel — same
                # iteration, one kernel per step instead of 3 matmuls).
                sqrtm_kw = (
                    {"sqrtm": wire_kernels.sqrtm_newton_schulz_fused}
                    if fused else {})
                eta_new = family_barycenter(
                    problem.global_family, eta_shipped, w_full, agg,
                    **sqrtm_kw)
            return theta_new, eta_new, opt_server, eta_L, opt_L, elbo_t

        return body

    # -- driver --------------------------------------------------------------

    def run(
        self,
        num_rounds: int,
        *,
        algorithm: str = "sfvi",
        local_steps: int = 1,
        scheduler: Optional[RoundScheduler] = None,
        callback: Optional[Callable[[int, dict], None]] = None,
        start_round: int = 0,
    ) -> Dict[str, list]:
        """Advance the federation ``num_rounds`` rounds; returns history.

        ``start_round`` is the absolute index of the first round: the
        round PRNG key, the scheduler's participation draws and the
        accountant's exchange indices are all functions of the absolute
        round, so ``run(a); run(b, start_round=a)`` replays exactly the
        same stream as one ``run(a + b)`` — the property
        ``federated.api.Experiment`` builds its bit-exact save/resume
        guarantee on.

        One round is ``local_steps`` optimizer steps: SFVI pays one
        up+down exchange per step, SFVI-Avg one per round — the meter
        (``self.comm``) records exactly that asymmetry. ``scheduler``
        injects partial participation / straggler masks: uninvited silos
        cost nothing; invited stragglers (dropout) receive the broadcast
        (download is billed) but never upload, and the aggregation is
        rescaled by the realized active count (unbiased, §3 Remark).

        With ``privacy`` set, each of the round's ``exchanges`` gathers
        is one (subsampled) Gaussian-mechanism invocation: the owned
        accountant composes them (q = the scheduler's invitation rate)
        and ``history["epsilon"]`` traces the cumulative ε at the
        policy's δ after each round. SFVI draws a FRESH participation
        mask for every local step (schedule index = exchange index
        ``r * local_steps + t``), so each gather is an independent
        subsampling event and the per-exchange amplification is sound;
        SFVI-Avg draws one mask per round (index ``r``).
        """
        if local_steps < 1:
            raise ValueError(f"local_steps must be >= 1, got {local_steps}")
        fn = self._get_round(algorithm, local_steps)
        sched = scheduler or RoundScheduler(self.J, seed=self.seed)
        up1 = self.bytes_up_per_silo(algorithm)
        down1 = self.bytes_down_per_silo()
        exchanges = local_steps if algorithm == "sfvi" else 1
        history: Dict[str, list] = {
            "elbo": [], "elbo_trace": [], "bytes_up": [], "bytes_down": [],
            "n_active": [],
        }
        if self.accountant is not None:
            history["epsilon"] = []
            # Poisson-q surrogate for the scheduler's fixed-size invitation
            # (docs/privacy.md §Accounting); custom schedulers without a
            # participation attribute are accounted at full participation.
            q = float(getattr(sched, "participation", 1.0))
        base_key = jax.random.PRNGKey(self.seed)
        for r in range(start_round, start_round + num_rounds):
            # SFVI synchronizes every local step, so each of the round's
            # `exchanges` gathers is its OWN participation draw (schedule
            # index = exchange index) — required for the accountant's
            # per-exchange subsampling amplification to be sound.
            # SFVI-Avg gathers once: one draw per round.
            ex_idx = ([r * local_steps + t for t in range(local_steps)]
                      if algorithm == "sfvi" else [r])
            ex_masks = [sched.mask(i) for i in ex_idx]
            active = [int(np.sum(np.asarray(m))) for m in ex_masks]
            # Stragglers received the broadcast before dropping: bill their
            # download. Custom schedulers without invited() bill reporters.
            invited = [
                max(int(np.sum(np.asarray(
                    sched.invited(i) if hasattr(sched, "invited")
                    else ex_masks[k]))), active[k])
                for k, i in enumerate(ex_idx)
            ]
            ex_masks = [self._pad_mask(m) for m in ex_masks]
            mask = (jnp.stack(ex_masks) if algorithm == "sfvi"
                    else ex_masks[0])
            round_key = jax.random.fold_in(base_key, r)
            # Sync rounds aggregate with the participation mask itself;
            # the async engine passes staleness-decayed weights instead.
            self.state, metrics = fn(self.state, self.data, round_key,
                                     mask, mask)
            elbos = np.asarray(metrics["elbo"])
            up = sum(active) * up1
            down = sum(invited) * down1
            n_active = active[-1]  # the round's final exchange
            self.comm.record(up, down)
            history["elbo"].append(float(elbos[-1]))
            history["elbo_trace"].extend(float(e) for e in elbos)
            history["bytes_up"].append(up)
            history["bytes_down"].append(down)
            history["n_active"].append(n_active)
            metrics_out = {
                "elbo": history["elbo"][-1], "bytes_up": up,
                "bytes_down": down, "n_active": n_active,
            }
            if self.accountant is not None:
                self.accountant.step(
                    noise_multiplier=self.privacy.noise_multiplier,
                    sampling_rate=q,
                    steps=exchanges,
                )
                eps = self.accountant.epsilon(self.privacy.delta)[0]
                history["epsilon"].append(eps)
                metrics_out["epsilon"] = eps
            if callback:
                callback(r, metrics_out)
        return history
