"""Tests for the 2-D (silo x model) mesh and the MeshSpec/RuntimeSpec API.

Covers the acceptance surface of the mesh redesign:
  * MeshSpec / RuntimeSpec JSON round trips and the CLI parse form;
  * build_mesh as the single factory (shapes, validation, axis helpers);
  * the deprecated out-of-band ``wire=`` kwarg warns once and still wins;
  * graph_cache tokens split on mesh shape (the stale-graph regression);
  * (slow, 8 forced host devices) J=64 trajectories: parameter state is
    bit-exact across every silo device count, and the 2-D
    (silo=4, model=2) mesh reproduces the 1-D silo=4 mesh bit-exactly
    INCLUDING the reported ELBO — plus the same equivalence for the
    paper's hier_bnn on a reduced backbone.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.federated import ExperimentSpec, MeshSpec, ModelSpec, RuntimeSpec
from repro.federated import api as api_mod
from repro.federated import graph_cache
from repro.federated.api import build
from repro.federated.scheduler import Scenario
from repro.launch.mesh import build_mesh, data_axes, data_world, model_world

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _toy_spec(**over):
    base = dict(model=ModelSpec("toy", {"num_obs": 8}),
                scenario=Scenario(algorithm="sfvi"),
                num_silos=4, rounds=2, local_steps=1)
    base.update(over)
    return ExperimentSpec(**base)


class TestMeshSpec:
    def test_json_round_trip(self):
        for spec in (MeshSpec(), MeshSpec(silo=8),
                     MeshSpec(silo=4, model=2, multiprocess=True)):
            d = json.loads(json.dumps(spec.to_dict()))
            assert MeshSpec.from_dict(d) == spec

    def test_parse(self):
        assert MeshSpec.parse("") == MeshSpec()
        assert MeshSpec.parse("silo=8") == MeshSpec(silo=8)
        assert MeshSpec.parse("silo=4,model=2") == MeshSpec(silo=4, model=2)
        assert MeshSpec.parse("silo=2,multiprocess") == MeshSpec(
            silo=2, multiprocess=True)
        assert MeshSpec.parse("multiprocess=true") == MeshSpec(
            multiprocess=True)
        with pytest.raises(ValueError, match="unknown mesh axis"):
            MeshSpec.parse("rows=2")

    def test_validation(self):
        with pytest.raises(ValueError):
            MeshSpec(model=0)
        with pytest.raises(ValueError):
            MeshSpec(silo=0)

    def test_runtime_spec_rides_the_experiment_spec(self):
        s = _toy_spec(runtime=RuntimeSpec(
            wire="fused", mesh=MeshSpec(silo=2, model=1), sanitize=True))
        assert ExperimentSpec.from_json(s.to_json()) == s
        d = s.to_dict()
        assert d["runtime"]["mesh"]["silo"] == 2
        assert d["runtime"]["wire"] == "fused"
        # Absent runtime node (old spec.json files) -> defaults.
        d.pop("runtime")
        old = ExperimentSpec.from_dict(d)
        assert old.runtime == RuntimeSpec()

    def test_runtime_spec_rejects_unknown_wire(self):
        with pytest.raises(ValueError, match="wire layout"):
            RuntimeSpec(wire="nope")


class TestBuildMesh:
    def test_single_factory_shapes(self):
        m = build_mesh(MeshSpec(), num_silos=4)
        assert m.axis_names == ("silo",)
        assert m.shape["silo"] >= 1
        assert data_axes(m) == ("silo",)
        assert data_world(m) == m.shape["silo"]
        assert model_world(m) == 1

    def test_pinned_silo_axis_validates_device_budget(self):
        import jax
        have = len(jax.local_devices())
        with pytest.raises(ValueError, match="devices"):
            build_mesh(MeshSpec(silo=have + 1))

    def test_model_axis_needs_devices(self):
        import jax
        have = len(jax.local_devices())
        with pytest.raises(ValueError, match="devices"):
            build_mesh(MeshSpec(model=have + 1))

    def test_back_compat_wrapper(self):
        from repro.launch.mesh import make_silo_mesh
        assert make_silo_mesh(4).axis_names == ("silo",)


class TestWireKwargDeprecation:
    def test_build_warns_once_and_kwarg_wins(self):
        api_mod._WIRE_KWARG_WARNED = False
        spec = _toy_spec(runtime=RuntimeSpec(wire="flat"))
        with pytest.warns(DeprecationWarning, match="wire= kwarg"):
            exp = build(spec, wire="legacy")
        assert exp.server.wire == "legacy"
        # Once per process: the second use is silent.
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("error")
            build(spec, wire="legacy")
        api_mod._WIRE_KWARG_WARNED = False

    def test_spec_runtime_wire_is_the_default(self):
        exp = build(_toy_spec(runtime=RuntimeSpec(wire="legacy")))
        assert exp.server.wire == "legacy"


class TestGraphCacheToken:
    def test_token_splits_on_mesh_shape(self):
        spec_json = _toy_spec().to_json(indent=0)
        t1 = graph_cache.build_token(spec_json, "flat", 4,
                                     mesh_shape=(("silo", 4),))
        t2 = graph_cache.build_token(spec_json, "flat", 4,
                                     mesh_shape=(("model", 2), ("silo", 2)))
        t3 = graph_cache.build_token(spec_json, "flat", 4,
                                     mesh_shape=(("silo", 8),))
        assert len({t1, t2, t3}) == 3
        assert t1 == graph_cache.build_token(spec_json, "flat", 4,
                                             mesh_shape=(("silo", 4),))


# ---------------------------------------------------------------------------
# 2-D mesh trajectory equivalence (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

_MESH2D_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
    import json
    import tempfile

    import jax
    import numpy as np
    from repro.federated import (Experiment, ExperimentSpec, MeshSpec,
                                 ModelSpec, RuntimeSpec, Scenario, build)

    assert jax.device_count() == 8

    def leaves(exp):
        st = exp.server.state
        keys = ("theta", "eta_G", "eta_L", "opt_server", "opt_local")
        return [np.asarray(x) for k in keys
                for x in jax.tree_util.tree_leaves(st[k])]

    def run(model, kwargs, J, mesh, rounds=3, steps=2):
        spec = ExperimentSpec(
            model=ModelSpec(model, kwargs),
            scenario=Scenario(algorithm="sfvi"),
            num_silos=J, rounds=rounds, local_steps=steps,
            runtime=RuntimeSpec(mesh=mesh))
        exp = build(spec)
        exp.run()
        return exp

    # --- toy, J=64 (divisible by every silo axis below) ------------------
    runs = {name: run("toy", {"num_obs": 8}, 64, mesh) for name, mesh in [
        ("1dev", MeshSpec(silo=1)),
        ("1d4", MeshSpec(silo=4)),
        ("1d8", MeshSpec(silo=8)),
        ("2d42", MeshSpec(silo=4, model=2)),
    ]}
    assert dict(runs["2d42"].server.mesh.shape) == {"silo": 4, "model": 2}
    assert dict(runs["1d8"].server.mesh.shape) == {"silo": 8}

    # Parameter state is bit-exact across EVERY topology (only the
    # reported ELBO scalar may differ across silo device counts — psum
    # association — and it never enters a parameter update).
    ref = leaves(runs["1dev"])
    for name in ("1d4", "1d8", "2d42"):
        got = leaves(runs[name])
        assert len(got) == len(ref), name
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a, b, err_msg=name)

    # The 2-D mesh reproduces its 1-D silo mesh bit-exactly INCLUDING
    # the reported ELBO: sharding P along the model axis must not move a
    # single bit anywhere.
    np.testing.assert_array_equal(
        np.asarray(runs["1d4"].history["elbo"], np.float64),
        np.asarray(runs["2d42"].history["elbo"], np.float64))
    # And across silo counts the ELBO still agrees to float tolerance.
    np.testing.assert_allclose(
        np.asarray(runs["1dev"].history["elbo"], np.float64),
        np.asarray(runs["1d8"].history["elbo"], np.float64),
        rtol=1e-5)
    print("TOY-OK")

    # --- resume across a topology change ---------------------------------
    # Save 2 rounds on the 1-D (silo=4) mesh, then resume with the mesh
    # changed to (silo=4, model=2) — the same spec.json edit the CLI's
    # ``--resume ... --mesh`` override performs. The checkpoint reshards
    # onto the 2-D mesh and the continued round matches the
    # uninterrupted 2-D run bit for bit.
    spec = ExperimentSpec(
        model=ModelSpec("toy", {"num_obs": 8}),
        scenario=Scenario(algorithm="sfvi"),
        num_silos=64, rounds=3, local_steps=2,
        runtime=RuntimeSpec(mesh=MeshSpec(silo=4)))
    exp = build(spec)
    exp.run(2)
    ckpt = tempfile.mkdtemp()
    exp.save(ckpt)
    sp = os.path.join(ckpt, "spec.json")
    with open(sp) as f:
        sd = json.load(f)
    sd["runtime"]["mesh"]["model"] = 2
    with open(sp, "w") as f:
        json.dump(sd, f)
    res = Experiment.resume(ckpt)
    assert dict(res.server.mesh.shape) == {"silo": 4, "model": 2}
    res.run()
    np.testing.assert_array_equal(
        np.asarray(res.history["elbo"], np.float64)[-1],
        np.asarray(runs["2d42"].history["elbo"], np.float64)[-1])
    for a, b in zip(leaves(res), leaves(runs["2d42"])):
        np.testing.assert_array_equal(a, b)
    print("RESUME-OK")

    # --- hier_bnn on a reduced backbone (acceptance criterion) -----------
    kw = {"hidden": 4, "in_dim": 16, "train_per_silo": 16,
          "test_per_silo": 8}
    b1 = run("hier_bnn", kw, 8, MeshSpec(silo=4), rounds=2)
    b2 = run("hier_bnn", kw, 8, MeshSpec(silo=4, model=2), rounds=2)
    np.testing.assert_array_equal(
        np.asarray(b1.history["elbo"], np.float64),
        np.asarray(b2.history["elbo"], np.float64))
    for a, b in zip(leaves(b1), leaves(b2)):
        np.testing.assert_array_equal(a, b)
    # The wire really is model-sharded: the compiled round gathers over
    # BOTH axes (silo blocks + the tiny model reconstruction gather).
    hlo = b2.server._lower(None, 2).compile().as_text()
    assert hlo.count("all-gather") >= 2, hlo.count("all-gather")
    print("BNN-OK")
""")


@pytest.mark.slow
def test_2d_mesh_matches_1d_and_single_device_trajectories():
    """Tentpole acceptance: on 8 forced host devices, J=64 parameter
    trajectories are bit-exact across 1/4/8-device silo axes and the
    (silo=4, model=2) mesh, and the 2-D mesh matches its 1-D silo mesh
    bit-exactly including the reported ELBO — same again for hier_bnn
    on a reduced backbone."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _MESH2D_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1800,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    for marker in ("TOY-OK", "RESUME-OK", "BNN-OK"):
        assert marker in out.stdout, (marker, out.stdout)
