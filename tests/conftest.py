"""Shared test fixtures. NOTE: do NOT set XLA_FLAGS device-count here —
smoke tests and benches must see the real single CPU device; only
launch/dryrun.py forces 512 placeholder devices (in its own process)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def pytest_collection_modifyitems(config, items):
    """Skip ONLY the genuinely compile-impossible cases off-TPU.

    Kernel suites run everywhere via ``interpret=True``; the
    ``tpu_only`` marker is reserved for tests of the compiled Mosaic
    lowering itself, which has no CPU equivalent. Never skip a whole
    module for a missing accelerator (or a missing optional dep — use a
    seeded fallback sweep instead, see test_kernels.py).
    """
    if not any(item.get_closest_marker("tpu_only") for item in items):
        return
    if jax.default_backend() == "tpu":
        return
    skip = pytest.mark.skip(
        reason="needs a TPU backend (compiled Mosaic path); CPU CI runs "
               "the interpret-mode equivalents")
    for item in items:
        if item.get_closest_marker("tpu_only"):
            item.add_marker(skip)
