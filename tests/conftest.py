"""Shared test fixtures. NOTE: do NOT set XLA_FLAGS device-count here —
smoke tests and benches must see the real single CPU device; only
launch/dryrun.py forces 512 placeholder devices (in its own process)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
