"""Posterior serving: q(Z_L|Z_G) queries from a federated checkpoint.

Covers the serving acceptance surface: checkpoint restore, joint
sampling through the problem's variational family, batched requests
grouped by silo (slices of one vectorized draw), determinism across
replicas, the predict hook, and the CLI endpoint.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.federated.api import ExperimentSpec, ModelSpec, build
from repro.federated.population import PopulationSpec
from repro.federated.scheduler import Scenario
from repro.federated.serve import Posterior, Query

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _toy_ckpt(tmp_path, **over):
    base = dict(model=ModelSpec("toy", {"num_obs": 16}),
                scenario=Scenario(algorithm="sfvi"),
                num_silos=3, rounds=2, seed=0)
    base.update(over)
    exp = build(ExperimentSpec(**base))
    exp.run()
    exp.save(str(tmp_path))
    return exp


class TestQuery:
    def test_validation(self):
        with pytest.raises(ValueError, match="kind"):
            Query("flarb")
        with pytest.raises(ValueError, match="silo"):
            Query("sample")
        with pytest.raises(ValueError, match="n must be"):
            Query("sample", silo=0, n=0)
        with pytest.raises(ValueError, match="inputs"):
            Query("predict", silo=0)

    def test_from_dict(self):
        q = Query.from_dict({"kind": "sample", "silo": 2, "n": 3})
        assert (q.kind, q.silo, q.n) == ("sample", 2, 3)


class TestPosterior:
    def test_joint_sampling_shapes_and_determinism(self, tmp_path):
        _toy_ckpt(tmp_path)
        post = Posterior.from_checkpoint(str(tmp_path))
        assert post.num_silos == 3 and post.round == 2
        s = post.sample(1, n=4, seed=9)
        assert np.asarray(s["z_G"]).shape == (4, 1)
        assert np.asarray(s["z_L"]).shape == (4, 1)
        # Same checkpoint + same seed on a second replica -> identical.
        replica = Posterior.from_checkpoint(str(tmp_path))
        s2 = replica.sample(1, n=4, seed=9)
        np.testing.assert_array_equal(np.asarray(s["z_G"]),
                                      np.asarray(s2["z_G"]))
        np.testing.assert_array_equal(np.asarray(s["z_L"]),
                                      np.asarray(s2["z_L"]))
        # Different silos draw from different streams.
        assert not np.array_equal(np.asarray(s["z_L"]),
                                  np.asarray(replica.sample(2, n=4,
                                                            seed=9)["z_L"]))

    def test_global_sample(self, tmp_path):
        _toy_ckpt(tmp_path)
        post = Posterior.from_checkpoint(str(tmp_path))
        z = post.global_sample(6, seed=1)
        assert np.asarray(z).shape == (6, 1)

    def test_silo_index_validated(self, tmp_path):
        _toy_ckpt(tmp_path)
        post = Posterior.from_checkpoint(str(tmp_path))
        with pytest.raises(IndexError, match="out of range"):
            post.sample(3)

    def test_samples_match_the_variational_family(self, tmp_path):
        """The serving path routes through SFVIProblem.sample_posterior:
        a direct (eager) call with the restored state + the same key
        gives the same draws — the endpoint adds batching and jit, not
        math (jit fusion may differ by float32 ULPs, hence allclose)."""
        _toy_ckpt(tmp_path)
        post = Posterior.from_checkpoint(str(tmp_path))
        got = post.sample(0, n=3, seed=5)
        prob = post.problem
        z_G, z_L = prob.sample_posterior(
            post.server.state["eta_G"], post.eta_row(0),
            post._key(5, 0), num_samples=3)
        np.testing.assert_allclose(np.asarray(got["z_G"]),
                                   np.asarray(z_G), rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(got["z_L"]),
                                   np.asarray(z_L), rtol=1e-6, atol=1e-7)

    def test_batched_queries_are_slices_of_one_grouped_draw(self, tmp_path):
        _toy_ckpt(tmp_path)
        post = Posterior.from_checkpoint(str(tmp_path))
        qs = [Query("sample", silo=1, n=2), Query("global_sample", n=2),
              Query("sample", silo=1, n=1), Query("sample", silo=0, n=1)]
        ans = post.answer_batch(qs, seed=0)
        grouped = post.sample(1, n=3, seed=0)
        np.testing.assert_array_equal(np.asarray(ans[0]["z_G"]),
                                      np.asarray(grouped["z_G"])[:2])
        np.testing.assert_array_equal(np.asarray(ans[2]["z_G"]),
                                      np.asarray(grouped["z_G"])[2:3])
        assert ans[1]["z_L"] is None
        assert np.asarray(ans[3]["z_G"]).shape == (1, 1)

    def test_serves_population_checkpoint_mid_roster(self, tmp_path):
        """A churn checkpoint restores with its live J; the endpoint
        serves exactly the joined silos."""
        exp = _toy_ckpt(
            tmp_path, num_silos=6, rounds=4,
            population=PopulationSpec(initial=2, arrival_rate=0.6,
                                      departure_rate=0.2, return_rate=0.5,
                                      seed=3))
        post = Posterior.from_checkpoint(str(tmp_path))
        assert post.num_silos == exp.population.state.joined
        s = post.sample(post.num_silos - 1, n=2)
        assert np.asarray(s["z_L"]).shape == (2, 1)
        with pytest.raises(IndexError):
            post.sample(post.num_silos)

    def test_predict_requires_model_hook(self, tmp_path):
        _toy_ckpt(tmp_path)
        post = Posterior.from_checkpoint(str(tmp_path))
        with pytest.raises(ValueError, match="predict hook"):
            post.predict(0, np.zeros((2, 1), np.float32))

    def test_predict_posterior_average(self, tmp_path):
        _toy_ckpt(tmp_path,
                  model=ModelSpec("hier_bnn",
                                  {"in_dim": 16, "hidden": 4,
                                   "train_per_silo": 16,
                                   "test_per_silo": 4}),
                  num_silos=2)
        post = Posterior.from_checkpoint(str(tmp_path))
        x = np.random.default_rng(0).normal(size=(5, 16)).astype(np.float32)
        out = post.predict(0, x, n=4, seed=2)
        assert np.asarray(out).shape == (5, 10)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(post.predict(0, x, n=4, seed=2)))


class TestCLI:
    def test_cli_answers_batched_queries(self, tmp_path):
        _toy_ckpt(tmp_path)
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run(
            [sys.executable, "-m", "repro.federated.serve",
             "--ckpt-dir", str(tmp_path), "--queries",
             json.dumps([{"kind": "sample", "silo": 0, "n": 2},
                         {"kind": "global_sample", "n": 1}])],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        payload = json.loads(out.stdout)
        assert payload["num_silos"] == 3 and payload["round"] == 2
        assert len(payload["answers"]) == 2
        assert np.asarray(payload["answers"][0]["z_G"]).shape == (2, 1)
        assert payload["answers"][1]["z_L"] is None
