"""Launch-layer tests: sharding rules, mesh construction, roofline parsing,
and a subprocess dry-run integration check."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.roofline import (
    active_param_count,
    analysis_variant,
    collective_bytes,
    extrapolate,
    model_flops,
)
from repro.launch.shardings import param_spec
from repro.models.backbone import transformer as T
from repro.models.backbone.config import INPUT_SHAPES

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _specs_for(cfg, model_size=16):
    params = jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: (path, leaf, param_spec(path, leaf, model_size)), params
    )


@pytest.mark.parametrize("arch", ["qwen3-8b", "olmoe-1b-7b", "zamba2-7b"])
def test_param_specs_divisible(arch):
    """Every sharded dim divides the model-axis size; sharded param count
    is substantial (tensor parallelism actually happens)."""
    cfg = get_config(arch)
    specs = _specs_for(cfg)
    sharded_bytes = total_bytes = 0
    for path, leaf, spec in jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
    ):
        nbytes = leaf.size * leaf.dtype.itemsize
        total_bytes += nbytes
        for dim, ax in enumerate(spec):
            if ax == "model":
                assert leaf.shape[dim] % 16 == 0, (path, leaf.shape, spec)
                sharded_bytes += nbytes
                break
    assert sharded_bytes / total_bytes > 0.9, (
        f"only {sharded_bytes/total_bytes:.0%} of params tensor-sharded")


def test_moe_experts_shard_on_expert_axis():
    cfg = get_config("olmoe-1b-7b")
    specs = _specs_for(cfg)
    moe = specs["units"]["slot0"]["moe"]
    for name in ("w_gate", "w_up", "w_down"):
        path, leaf, spec = moe[name]
        assert spec[1] == "model", (name, spec)  # dim 0 is the unit stack


def test_collective_bytes_parser():
    hlo = """
  %ar = bf16[16,1024]{1,0} all-reduce(bf16[16,1024]{1,0} %x), replica_groups=
  %ag.1 = f32[512]{0} all-gather(f32[32]{0} %y), dimensions={0}
  %a2a = (f32[8,4]{1,0}, f32[8,4]{1,0}) all-to-all(%p, %q)
  %cp = u32[128]{0} collective-permute(u32[128]{0} %z)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 16 * 1024 * 2 * 2.0
    assert got["all-gather"] == 512 * 4
    assert got["all-to-all"] == 2 * 8 * 4 * 4
    assert got["collective-permute"] == 128 * 4


def test_extrapolation_linear():
    m1 = {"flops": 10.0, "bytes": 4.0, "coll": 2.0, "coll_breakdown": {"all-reduce": 2.0}}
    m2 = {"flops": 16.0, "bytes": 6.0, "coll": 3.0, "coll_breakdown": {"all-reduce": 3.0}}
    out = extrapolate(m1, m2, 10)
    assert out["flops"] == 10 + 9 * 6
    assert out["coll_breakdown"]["all-reduce"] == 2 + 9 * 1


def test_analysis_variant_preserves_family():
    cfg = get_config("zamba2-7b")
    v = analysis_variant(cfg, 2)
    assert v.analysis_mode and v.num_layers == 2 * 6 + 81 % 6
    assert v.block_kind(5) == "attn"  # pattern intact


@pytest.mark.parametrize("arch", ["qwen3-8b", "olmoe-1b-7b", "xlstm-1.3b"])
def test_model_flops_sane(arch):
    """6*N*D within 2x of the naive param-count estimate."""
    cfg = get_config(arch)
    n_active = active_param_count(cfg)
    assert n_active > 1e8
    f = model_flops(cfg, INPUT_SHAPES["train_4k"])
    assert f == 6.0 * n_active * 4096 * 256


@pytest.mark.slow
def test_dryrun_subprocess_one_combo():
    """The real thing: 512 host devices, production mesh, lower + compile.
    Uses the cheapest (arch, shape) cell to keep CI time sane."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-base", "--shape", "decode_32k"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1500,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "0 failed" in out.stdout
