"""Property tests for repro.federated.aggregation.

Two tiers so the invariants are exercised everywhere:

  * hypothesis-driven property tests (CI installs hypothesis) explore
    the input space adversarially;
  * seeded numpy sweeps over many random cases run even where
    hypothesis is absent (the offline container), so the same
    invariants always have local coverage.

Invariants under test:
  * permutation invariance: relabeling silos never changes the
    aggregate (mean and trimmed mean);
  * inactive-silo independence: values carried by masked-out silos
    can be anything — the aggregate must not move;
  * mean == numpy masked mean;
  * int8 codec: decode(encode(x)) is within half a quantization step
    (scale = max|x|/127) of x, per coordinate, and the wire is smaller.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federated import (
    Int8Compressor,
    MeanAggregator,
    NoCompression,
    TrimmedMeanAggregator,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # offline container: seeded sweeps below still run
    HAVE_HYPOTHESIS = False

AGGREGATORS = [MeanAggregator(), TrimmedMeanAggregator(0.1),
               TrimmedMeanAggregator(0.25)]


def _random_case(rng, max_silos=8, max_dim=6):
    """One (stacked, mask) draw with at least one active silo."""
    J = int(rng.integers(2, max_silos + 1))
    d = int(rng.integers(1, max_dim + 1))
    stacked = {"g": jnp.asarray(rng.normal(0, 10, (J, d)).astype(np.float32)),
               "h": jnp.asarray(rng.normal(0, 1, (J,)).astype(np.float32))}
    mask = (rng.random(J) < 0.7).astype(np.float32)
    if mask.sum() == 0:
        mask[int(rng.integers(J))] = 1.0
    return stacked, jnp.asarray(mask)


def _assert_trees_close(a, b, **kw):
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]), **kw)


class TestPermutationInvariance:
    @pytest.mark.parametrize("agg", AGGREGATORS, ids=lambda a: repr(a))
    def test_seeded_sweep(self, agg):
        rng = np.random.default_rng(0)
        for _ in range(25):
            stacked, mask = _random_case(rng)
            perm = rng.permutation(mask.shape[0])
            out = agg.combine(stacked, mask)
            out_p = agg.combine(
                {k: v[perm] for k, v in stacked.items()}, mask[perm])
            _assert_trees_close(out, out_p, rtol=1e-5, atol=1e-5)

    if HAVE_HYPOTHESIS:

        @settings(max_examples=50, deadline=None)
        @given(st.integers(0, 2**32 - 1), st.sampled_from(range(len(AGGREGATORS))))
        def test_hypothesis(self, seed, agg_i):
            rng = np.random.default_rng(seed)
            stacked, mask = _random_case(rng)
            perm = rng.permutation(mask.shape[0])
            agg = AGGREGATORS[agg_i]
            out = agg.combine(stacked, mask)
            out_p = agg.combine(
                {k: v[perm] for k, v in stacked.items()}, mask[perm])
            _assert_trees_close(out, out_p, rtol=1e-5, atol=1e-5)


class TestInactiveSiloIndependence:
    @pytest.mark.parametrize("agg", AGGREGATORS, ids=lambda a: repr(a))
    def test_seeded_sweep(self, agg):
        """Garbage (even huge values) in masked-out rows changes nothing."""
        rng = np.random.default_rng(1)
        for _ in range(25):
            stacked, mask = _random_case(rng)
            if float(jnp.sum(mask)) == mask.shape[0]:
                mask = mask.at[0].set(0.0)  # force an inactive silo
            inactive = (np.asarray(mask) < 0.5)
            poisoned = {}
            for k, v in stacked.items():
                arr = np.asarray(v).copy()
                arr[inactive] = rng.normal(0, 1e6, arr[inactive].shape)
                poisoned[k] = jnp.asarray(arr)
            out = agg.combine(stacked, mask)
            out_p = agg.combine(poisoned, mask)
            _assert_trees_close(out, out_p, rtol=1e-5, atol=1e-5)

    if HAVE_HYPOTHESIS:

        @settings(max_examples=50, deadline=None)
        @given(st.integers(0, 2**32 - 1), st.sampled_from(range(len(AGGREGATORS))),
               st.floats(1.0, 1e8))
        def test_hypothesis(self, seed, agg_i, poison_scale):
            rng = np.random.default_rng(seed)
            stacked, mask = _random_case(rng)
            if float(jnp.sum(mask)) == mask.shape[0]:
                mask = mask.at[0].set(0.0)
            inactive = (np.asarray(mask) < 0.5)
            poisoned = {}
            for k, v in stacked.items():
                arr = np.asarray(v).copy()
                arr[inactive] = poison_scale
                poisoned[k] = jnp.asarray(arr)
            agg = AGGREGATORS[agg_i]
            _assert_trees_close(agg.combine(stacked, mask),
                                agg.combine(poisoned, mask),
                                rtol=1e-5, atol=1e-5)


class TestFractionalWeights:
    """The async staleness-decay regression: weights summing below 1
    must NOT shrink the aggregate (the denominator guards only exact
    zero, not < 1). A single stale arrival with weight 0.25 used to be
    divided by 1.0 — a 4× silent shrink of a PARAMETER upload."""

    def test_single_stale_arrival_is_returned_unscaled(self):
        agg = MeanAggregator()
        x = jnp.asarray(np.arange(1.0, 7.0, dtype=np.float32).reshape(2, 3))
        w = jnp.asarray(np.array([0.25, 0.0], np.float32))
        out = agg.combine({"g": x}, w)
        np.testing.assert_allclose(np.asarray(out["g"]), np.asarray(x[0]),
                                   rtol=1e-6)

    def test_weighted_mean_for_sub_unit_totals(self):
        rng = np.random.default_rng(7)
        agg = MeanAggregator()
        for _ in range(25):
            stacked, _ = _random_case(rng)
            J = next(iter(stacked.values())).shape[0]
            # Fractional staleness-style weights with Σw < 1.
            w = rng.uniform(0.0, 0.3, J).astype(np.float32)
            w[int(rng.integers(J))] = max(w.max(), 0.05)
            assert 0.0 < w.sum() < 1.0 or w.sum() >= 1.0  # any total
            out = agg.combine(stacked, jnp.asarray(w))
            for k, v in stacked.items():
                arr = np.asarray(v)
                ww = w.reshape(-1, *([1] * (arr.ndim - 1)))
                ref = (arr * ww).sum(axis=0) / w.sum()
                np.testing.assert_allclose(np.asarray(out[k]), ref,
                                           rtol=1e-5, atol=1e-5)

    def test_scalar_weight_invariance(self):
        """A weighted mean is invariant to rescaling ALL weights — the
        property the old 1.0-clamp broke for totals below 1."""
        rng = np.random.default_rng(8)
        agg = MeanAggregator()
        stacked, mask = _random_case(rng)
        a = agg.combine(stacked, mask)
        b = agg.combine(stacked, mask * 0.1)
        _assert_trees_close(a, b, rtol=1e-5, atol=1e-5)

    def test_zero_total_still_guarded(self):
        agg = MeanAggregator()
        out = agg.combine({"g": jnp.ones((3, 2))}, jnp.zeros((3,)))
        np.testing.assert_allclose(np.asarray(out["g"]), 0.0)


class TestTrimmedBreakdown:
    """Degenerate trimmed-mean inputs, identical across all three
    implementations: the live ``TrimmedMeanAggregator``, the pure-jnp
    oracle (``kernels/ref.py``) and the fused Pallas combine kernel.

    The breakdown cases the trim formula must survive: J=1 and J=2
    (floor((n−1)/2) forces k=0 — nothing to trim without losing every
    vote), all silos masked out, and fractional async weights summing
    below 1 (rank statistics count votes, not weight mass).
    """

    @staticmethod
    def _all_three(x, w, trim_frac):
        from repro.kernels import ops, ref

        agg = TrimmedMeanAggregator(trim_frac)
        live = jnp.asarray(agg.combine(x, w))
        oracle = ref.masked_trimmed_mean_ref(x, w, trim_frac)
        fused = ops.wire_combine(x, w, trim_frac=trim_frac)
        np.testing.assert_allclose(np.asarray(live), np.asarray(oracle),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(live), np.asarray(fused),
                                   rtol=1e-6, atol=1e-6)
        return np.asarray(live)

    @pytest.mark.parametrize("trim_frac", [0.1, 0.25, 0.49])
    def test_single_silo_is_identity(self, trim_frac):
        x = jnp.asarray([[3.0, -1.5, 0.25]])
        out = self._all_three(x, jnp.ones((1,)), trim_frac)
        np.testing.assert_allclose(out, np.asarray(x[0]), rtol=1e-6)

    @pytest.mark.parametrize("trim_frac", [0.1, 0.25, 0.49])
    def test_two_silos_trim_nothing(self, trim_frac):
        """n=2 -> k = min(floor(2·tf), floor(1/2)) = 0: plain mean of
        both votes, never a degenerate single-survivor pick."""
        x = jnp.asarray([[10.0, -4.0], [2.0, 8.0]])
        out = self._all_three(x, jnp.ones((2,)), trim_frac)
        np.testing.assert_allclose(out, np.asarray(x).mean(axis=0),
                                   rtol=1e-6)

    @pytest.mark.parametrize("trim_frac", [0.1, 0.3])
    def test_all_masked_returns_zeros(self, trim_frac):
        """Zero active silos: without a guard the +inf sentinel fills
        every rank and the 'mean' is inf — all three implementations
        must return zeros instead (MeanAggregator's zero-total rule)."""
        x = jnp.asarray(np.random.default_rng(5).normal(
            0, 10, (4, 3)).astype(np.float32))
        out = self._all_three(x, jnp.zeros((4,)), trim_frac)
        np.testing.assert_array_equal(out, np.zeros((3,), np.float32))

    def test_subunit_fractional_weights_count_as_full_votes(self):
        """Stale async arrivals carry fractional weight, but the rank
        statistics treat every w > 0 silo as one vote: scaling all
        weights below 1 must not change the trimmed mean."""
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.normal(0, 5, (6, 4)).astype(np.float32))
        w_full = jnp.asarray((rng.random(6) < 0.8).astype(np.float32))
        w_frac = w_full * jnp.asarray(
            rng.uniform(0.01, 0.15, 6).astype(np.float32))
        assert float(jnp.sum(w_frac)) < 1.0
        a = self._all_three(x, w_full, 0.25)
        b = self._all_three(x, w_frac, 0.25)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_seeded_degenerate_sweep(self):
        rng = np.random.default_rng(9)
        for _ in range(20):
            J = int(rng.integers(1, 5))
            d = int(rng.integers(1, 5))
            x = jnp.asarray(rng.normal(0, 10, (J, d)).astype(np.float32))
            w = jnp.asarray((rng.random(J) < 0.5).astype(np.float32)
                            * rng.uniform(0.05, 1.0, J).astype(np.float32))
            for tf in (0.1, 0.25, 0.49):
                out = self._all_three(x, w, tf)
                assert np.all(np.isfinite(out))

    if HAVE_HYPOTHESIS:

        @settings(max_examples=50, deadline=None)
        @given(st.integers(0, 2**32 - 1), st.integers(1, 6),
               st.sampled_from([0.1, 0.25, 0.49]))
        def test_hypothesis(self, seed, J, trim_frac):
            rng = np.random.default_rng(seed)
            x = jnp.asarray(rng.normal(0, 10, (J, 3)).astype(np.float32))
            w = jnp.asarray((rng.random(J) < 0.6).astype(np.float32)
                            * rng.uniform(0.01, 1.0, J).astype(np.float32))
            out = self._all_three(x, w, trim_frac)
            assert np.all(np.isfinite(out))


class TestMeanIsMaskedMean:
    def test_seeded_sweep(self):
        rng = np.random.default_rng(2)
        agg = MeanAggregator()
        for _ in range(25):
            stacked, mask = _random_case(rng)
            out = agg.combine(stacked, mask)
            m = np.asarray(mask)
            for k, v in stacked.items():
                arr = np.asarray(v)
                mm = m.reshape(-1, *([1] * (arr.ndim - 1)))
                ref = (arr * mm).sum(axis=0) / m.sum()
                np.testing.assert_allclose(np.asarray(out[k]), ref,
                                           rtol=1e-5, atol=1e-5)


class TestInt8ErrorBound:
    """decode∘encode error is bounded by half a quantization step."""

    @staticmethod
    def _check(x):
        comp = Int8Compressor()
        dec = comp.decode(comp.encode({"x": x}))["x"]
        scale = float(jnp.max(jnp.abs(x))) / 127.0 + 1e-12
        err = np.max(np.abs(np.asarray(dec) - np.asarray(x)))
        assert err <= 0.5 * scale + 1e-6, (err, scale)

    def test_seeded_sweep(self):
        rng = np.random.default_rng(3)
        for _ in range(50):
            shape = tuple(rng.integers(1, 9, size=int(rng.integers(1, 3))))
            scale = 10.0 ** rng.uniform(-3, 3)
            x = jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))
            self._check(x)

    def test_wire_strictly_smaller_above_scale_overhead(self):
        tree = {"a": jnp.ones((64,)), "b": jnp.ones((8, 8))}
        assert Int8Compressor().wire_bytes(tree) < NoCompression().wire_bytes(tree)

    if HAVE_HYPOTHESIS:

        @settings(max_examples=100, deadline=None)
        @given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32),
                        min_size=1, max_size=64))
        def test_hypothesis(self, values):
            self._check(jnp.asarray(np.asarray(values, np.float32)))
