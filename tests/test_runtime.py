"""Integration tests for the federated runtime (Algorithms 1 & 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ConditionalGaussian,
    DiagGaussian,
    SFVIAvgServer,
    SFVIProblem,
    SFVIServer,
    Silo,
    StructuredModel,
    tree_bytes,
)
from repro.optim import adam


def _toy_problem(dG=2, dL=3):
    def log_prior_global(theta, zg):
        return -0.5 * jnp.sum(zg**2)

    def log_local(theta, zg, zl, data):
        return -0.5 * jnp.sum((zl - jnp.mean(zg)) ** 2) - 2.0 * jnp.sum(
            (data - zl[None, :]) ** 2
        )

    model = StructuredModel(
        global_dim=dG, local_dim=dL,
        log_prior_global=log_prior_global, log_local=log_local,
    )
    return SFVIProblem(model, DiagGaussian(dG), ConditionalGaussian(dL, dG))


def _make_silos(prob, J=3, n=5, lr=5e-2, seed=0):
    datas = [
        jax.random.normal(jax.random.PRNGKey(100 + seed + j), (n, prob.model.local_dim))
        for j in range(J)
    ]
    return [
        Silo(j, prob, datas[j], prob.local_family.init(jax.random.PRNGKey(seed + j)),
             adam(lr), n)
        for j in range(J)
    ]


class TestSFVIServer:
    def test_elbo_improves(self):
        prob = _toy_problem()
        silos = _make_silos(prob)
        srv = SFVIServer(prob, silos, {}, prob.global_family.init(jax.random.PRNGKey(1)), adam(5e-2))
        h = srv.run(200)
        assert np.mean(h["elbo"][-20:]) > np.mean(h["elbo"][:20])

    def test_no_nans(self):
        prob = _toy_problem()
        silos = _make_silos(prob)
        srv = SFVIServer(prob, silos, {}, prob.global_family.init(jax.random.PRNGKey(1)), adam(5e-2))
        h = srv.run(50)
        assert np.all(np.isfinite(h["elbo"]))
        for leaf in jax.tree_util.tree_leaves(srv.eta_G):
            assert bool(jnp.all(jnp.isfinite(leaf)))

    def test_communication_is_global_sized_only(self):
        """The up-link carries ONLY global-shaped gradients — nothing scaling
        with local latent dims or data size (the paper's privacy property)."""
        prob = _toy_problem(dG=2, dL=50)
        silos = _make_silos(prob, J=2, n=40)
        srv = SFVIServer(prob, silos, {}, prob.global_family.init(jax.random.PRNGKey(1)), adam(1e-2))
        h = srv.run(3)
        # up-link per silo per round = g_theta (empty) + g_eta (2*dG floats)
        expected_up_per_silo = 2 * 2 * 4  # mu+log_sigma, dG=2, f32
        assert h["bytes_up"][0] == 2 * expected_up_per_silo

    def test_partial_participation_still_converges(self):
        prob = _toy_problem()
        silos = _make_silos(prob, J=4)
        srv = SFVIServer(prob, silos, {}, prob.global_family.init(jax.random.PRNGKey(1)), adam(5e-2))
        h = srv.run(300, participation=0.5)
        assert np.mean(h["elbo"][-20:]) > np.mean(h["elbo"][:20])

    def test_local_params_never_in_messages(self):
        """Structural privacy check: reply trees contain no local-dim leaves."""
        prob = _toy_problem(dG=2, dL=17)
        silo = _make_silos(prob, J=1)[0]
        eps_G = jax.random.normal(jax.random.PRNGKey(0), (2,))
        reply = silo.sfvi_step({"theta": {}, "eta_G": prob.global_family.init(jax.random.PRNGKey(1)), "eps_G": eps_G})
        for leaf in jax.tree_util.tree_leaves(reply):
            assert 17 not in leaf.shape


class TestSFVIAvgServer:
    def test_elbo_improves(self):
        """Late-window mean ELBO beats the early window by more than the
        estimator noise. The per-round ELBO is a single-sample MC
        estimate, so comparing two individual draws (first vs last) is a
        coin flip once the optimizer has converged — the old 25-step
        rounds converged inside round 0, leaving only noise to compare.
        Short rounds keep real signal across the run, the run is seeded,
        and the tolerance is derived from the within-window variance of
        the estimates themselves (2x the pooled standard error) instead
        of a magic constant."""
        prob = _toy_problem()
        silos = _make_silos(prob, lr=2e-2, seed=0)
        srv = SFVIAvgServer(prob, silos, {},
                            prob.global_family.init(jax.random.PRNGKey(1)),
                            lambda: adam(2e-2), seed=0)
        h = srv.run(12, local_steps=3)
        elbo = np.asarray(h["elbo"])
        early, late = elbo[:3], elbo[-3:]
        pooled_se = np.sqrt(np.var(early, ddof=1) / early.size
                            + np.var(late, ddof=1) / late.size)
        assert late.mean() - early.mean() > 2.0 * pooled_se, (
            f"improvement {late.mean() - early.mean():.3f} not significant "
            f"vs estimator noise (2*SE = {2 * pooled_se:.3f}); trace {elbo}")

    def test_fewer_rounds_than_sfvi_for_same_steps(self):
        """Communication efficiency: m local steps per round -> 1 round of
        communication instead of m (the paper's whole point for SFVI-Avg)."""
        prob = _toy_problem()
        silos_a = _make_silos(prob)
        srv_a = SFVIServer(prob, silos_a, {}, prob.global_family.init(jax.random.PRNGKey(1)), adam(5e-2))
        h_a = srv_a.run(100)

        silos_b = _make_silos(prob)
        srv_b = SFVIAvgServer(prob, silos_b, {}, prob.global_family.init(jax.random.PRNGKey(1)), lambda: adam(5e-2))
        h_b = srv_b.run(4, local_steps=25)  # same 100 gradient steps

        assert srv_b.comm.rounds < srv_a.comm.rounds
        assert srv_b.comm.total < srv_a.comm.total
        # And it still reaches a comparable ELBO neighbourhood (coarse check).
        assert h_b["elbo"][-1] > h_a["elbo"][0]

    def test_barycenter_of_identical_silos_is_identity(self):
        """If all silos return the same η_G, averaging must not move it."""
        prob = _toy_problem()
        fam = prob.global_family
        eta = fam.init(jax.random.PRNGKey(0))
        srv = SFVIAvgServer(prob, _make_silos(prob), {}, eta, lambda: adam(1e-2))
        out = srv._barycenter([eta, eta, eta])
        for k in eta:
            np.testing.assert_allclose(out[k], eta[k], rtol=1e-5)


class TestTreeBytes:
    def test_counts_f32(self):
        assert tree_bytes({"a": jnp.zeros((3, 4), jnp.float32)}) == 48

    def test_empty(self):
        assert tree_bytes({}) == 0
