"""Pallas GLA/SSD kernel vs the exact-recurrence oracle and the jnp
chunked path (shape/dtype sweep + hypothesis property)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed; pip install -e .[test]")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.ref import gla_chunk_ref
from repro.models.backbone.ssm import chunked_gla

KEY = jax.random.PRNGKey(11)


def _inputs(B, S, H, dk, dv, seed=0):
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 4)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    a = -jnp.abs(0.3 * jax.random.normal(ks[3], (B, S, H)))
    return q, k, v, a


@pytest.mark.parametrize(
    "B,S,H,dk,dv,chunk",
    [(2, 64, 3, 8, 5, 16), (1, 200, 2, 64, 64, 128), (1, 33, 4, 16, 16, 8),
     (2, 128, 2, 32, 64, 64)],
)
def test_gla_kernel_matches_exact_recurrence(B, S, H, dk, dv, chunk):
    q, k, v, a = _inputs(B, S, H, dk, dv)
    y = ops.gla(q, k, v, a, chunk=chunk)
    scale = 1.0
    for b in range(B):
        y_exact, _ = gla_chunk_ref(q[b], k[b], v[b], a[b])
        scale = max(scale, float(jnp.abs(y_exact).max()))
        np.testing.assert_allclose(
            np.asarray(y[b]), np.asarray(y_exact),
            atol=3e-6 * scale, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gla_kernel_dtypes(dtype):
    q, k, v, a = _inputs(1, 96, 2, 16, 16)
    y = ops.gla(q.astype(dtype), k.astype(dtype), v.astype(dtype), a, chunk=32)
    y_ref = chunked_gla(q, k, v, a)
    tol = 6e-2 if dtype == jnp.bfloat16 else 1e-4
    scale = float(jnp.abs(y_ref).max())
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        atol=tol * scale, rtol=tol)


@given(s=st.integers(2, 80), chunk=st.sampled_from([8, 16, 32]))
@settings(max_examples=10, deadline=None)
def test_gla_kernel_chunk_invariance(s, chunk):
    """Property: the kernel result is independent of the chunk tiling."""
    q, k, v, a = _inputs(1, s, 2, 8, 8, seed=s)
    y1 = ops.gla(q, k, v, a, chunk=chunk)
    y2 = ops.gla(q, k, v, a, chunk=min(64, ((s + 7) // 8) * 8))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5,
                               rtol=2e-4)
