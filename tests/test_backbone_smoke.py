"""Per-architecture smoke tests (assignment requirement f).

Each assigned architecture is instantiated as its REDUCED variant
(2 layers, d_model <= 128, <= 4 experts) and must:
  * run one forward pass with correct output shape and no NaNs;
  * run one SFVI train step on CPU (loss finite, params update);
  * stream prefill -> decode consistently with the teacher-forced forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.launch import steps as S
from repro.models.backbone import transformer as T

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B, Sq, labels=True):
    batch = {"tokens": jax.random.randint(KEY, (B, Sq), 0, cfg.vocab_size)}
    if labels:
        batch["labels"] = jax.random.randint(KEY, (B, Sq), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32
        )
    if cfg.num_vision_tokens:
        batch["vision"] = jax.random.normal(
            KEY, (B, cfg.num_vision_tokens, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(KEY, cfg)
    B, Sq = 2, 16
    logits, aux, h = T.forward(params, cfg, make_batch(cfg, B, Sq, labels=False),
                               remat=False)
    assert logits.shape == (B, Sq, cfg.vocab_size)
    assert h.shape == (B, Sq, cfg.d_model)
    assert not jnp.isnan(logits).any()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    num_silos = 2
    state, _ = S.init_train_state(KEY, cfg, num_silos, lr=1e-3)
    step = S.make_train_step(cfg, num_silos, lr=1e-3, remat=False)
    batch = make_batch(cfg, 4, 16)
    new_state, metrics = jax.jit(step)(state, batch, jnp.int32(0))
    assert jnp.isfinite(metrics["loss"]), metrics
    assert int(new_state.step) == 1
    # parameters actually moved
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) -
                                   b.astype(jnp.float32)).max()),
        state.theta, new_state.theta)
    assert max(jax.tree_util.tree_leaves(diff)) > 0


@pytest.mark.parametrize(
    "arch",
    ["qwen3-4b", "zamba2-7b", "xlstm-1.3b", "olmoe-1b-7b", "qwen2-vl-2b",
     "whisper-base"],
)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.is_moe:  # capacity drops differ between paths; use drop-free cfg
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_params(KEY, cfg)
    B, Sq = 2, 12
    batch = make_batch(cfg, B, Sq, labels=False)
    tokens = batch["tokens"]
    full, _, _ = T.forward(params, cfg, batch, remat=False)
    pre = dict(batch)
    pre["tokens"] = tokens[:, : Sq - 3]
    max_len = Sq + cfg.num_vision_tokens + 4
    logits_p, cache, _ = T.prefill(params, cfg, pre, max_len=max_len)
    errs = [float(jnp.abs(logits_p[:, 0] - full[:, Sq - 4]).max())]
    for t in range(Sq - 3, Sq):
        lg, cache, _ = T.decode_step(params, cfg, tokens[:, t : t + 1], cache)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 5e-4, errs


def test_sliding_window_decode_ring_buffer():
    """Dense arch + sliding window: decode past the window stays finite and
    matches teacher-forced forward with the same window."""
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(), sliding_window=8)
    params = T.init_params(KEY, cfg)
    B, Sq = 1, 20
    tokens = jax.random.randint(KEY, (B, Sq), 0, cfg.vocab_size)
    full, _, _ = T.forward(params, cfg, {"tokens": tokens}, remat=False)
    logits_p, cache, _ = T.prefill(
        params, cfg, {"tokens": tokens[:, :10]}, max_len=Sq
    )
    errs = [float(jnp.abs(logits_p[:, 0] - full[:, 9]).max())]
    for t in range(10, Sq):
        lg, cache, _ = T.decode_step(params, cfg, tokens[:, t : t + 1], cache)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 5e-4, errs


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_config_exact_dims(arch):
    """The FULL configs match the assignment table exactly."""
    expect = {
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect
    assert cfg.source  # every config cites its source
    # family-specific structure
    if arch == "zamba2-7b":
        assert cfg.ssm_state == 64 and cfg.shared_attn
    if arch == "olmoe-1b-7b":
        assert (cfg.num_experts, cfg.num_experts_per_tok) == (64, 8)
    if arch == "phi3.5-moe-42b-a6.6b":
        assert (cfg.num_experts, cfg.num_experts_per_tok) == (16, 2)
    if arch == "xlstm-1.3b":
        assert cfg.slstm_period == 8
    if arch == "whisper-base":
        assert cfg.is_encoder_decoder and cfg.num_encoder_layers == 6
    if arch == "qwen2-vl-2b":
        assert cfg.mrope and cfg.num_vision_tokens > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_within_limits(arch):
    r = get_config(arch).reduced()
    assert r.num_layers <= 2
    assert r.d_model <= 512
    assert r.num_experts <= 4
