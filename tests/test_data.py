"""Tests for the synthetic data pipeline and silo partitioners."""
import jax
import numpy as np
import pytest

from repro.data import (
    dirichlet_label_partition,
    heterogeneous_label_partition,
    iid_partition,
    make_lda_corpus,
    make_six_cities,
    make_synthetic_mnist,
    make_token_stream,
    pad_ragged_silos,
    sizes_partition,
)


class TestGenerators:
    def test_synthetic_mnist_shapes(self):
        tr, te = make_synthetic_mnist(jax.random.PRNGKey(0), 100, 20, dim=64, num_classes=5)
        assert tr.x.shape == (100, 64) and tr.y.shape == (100,)
        assert te.x.shape == (20, 64)
        assert tr.y.min() >= 0 and tr.y.max() < 5
        assert np.isfinite(tr.x).all()

    def test_synthetic_mnist_is_learnable(self):
        """Nearest-prototype classification must beat chance by a wide margin
        (otherwise the BNN experiments are meaningless)."""
        tr, te = make_synthetic_mnist(jax.random.PRNGKey(0), 2000, 500, dim=784)
        protos = np.stack([tr.x[tr.y == c].mean(0) for c in range(10)])
        pred = np.argmin(
            ((te.x[:, None, :] - protos[None]) ** 2).sum(-1), axis=1
        )
        assert (pred == te.y).mean() > 0.8

    def test_lda_corpus(self):
        counts, topics = make_lda_corpus(
            jax.random.PRNGKey(1), num_docs=50, vocab_size=100, num_topics=7
        )
        assert counts.shape == (50, 100)
        assert topics.shape == (7, 100)
        np.testing.assert_allclose(topics.sum(-1), 1.0, rtol=1e-4)
        assert counts.sum(-1).min() >= 10  # doc length floor

    def test_six_cities(self):
        data, truth = make_six_cities(jax.random.PRNGKey(2), num_children=100)
        assert data["y"].shape == (100, 4)
        assert set(np.unique(data["y"])) <= {0.0, 1.0}
        assert data["age"].shape == (100, 4)
        np.testing.assert_array_equal(data["age"][0], [-2, -1, 0, 1])

    def test_token_stream(self):
        toks = make_token_stream(jax.random.PRNGKey(3), 10_000, vocab_size=1000)
        assert toks.shape == (10_000,)
        # Zipf: the most common token is much more frequent than the median.
        counts = np.bincount(toks, minlength=1000)
        assert counts.max() > 20 * max(np.median(counts), 1)


class TestPartitioners:
    def test_iid_partition_covers_everything(self):
        rng = np.random.default_rng(0)
        parts = iid_partition(rng, 103, 4)
        allidx = np.concatenate(parts)
        assert len(allidx) == 103
        assert len(np.unique(allidx)) == 103

    def test_sizes_partition(self):
        rng = np.random.default_rng(0)
        parts = sizes_partition(rng, 537, [300, 237])
        assert len(parts[0]) == 300 and len(parts[1]) == 237
        assert len(np.unique(np.concatenate(parts))) == 537

    def test_sizes_partition_validates(self):
        rng = np.random.default_rng(0)
        with pytest.raises(AssertionError):
            sizes_partition(rng, 10, [3, 3])

    def test_heterogeneous_partition_skew(self):
        """Each silo must be ~90% one label — the paper's §4.1 protocol."""
        rng = np.random.default_rng(0)
        labels = rng.integers(0, 10, size=10_000)
        parts = heterogeneous_label_partition(rng, labels, 10, dominant_frac=0.9)
        for j, p in enumerate(parts):
            silo_labels = labels[p]
            dom = np.bincount(silo_labels, minlength=10).max() / len(silo_labels)
            assert dom > 0.8, f"silo {j} dominant fraction {dom}"

    def test_heterogeneous_partition_disjoint(self):
        rng = np.random.default_rng(1)
        labels = rng.integers(0, 10, size=5000)
        parts = heterogeneous_label_partition(rng, labels, 50)
        allidx = np.concatenate(parts)
        assert len(np.unique(allidx)) == len(allidx)

    def test_heterogeneous_partition_50_silos(self):
        """The paper's J=50 configuration must also produce skewed silos."""
        rng = np.random.default_rng(2)
        labels = rng.integers(0, 10, size=20_000)
        parts = heterogeneous_label_partition(rng, labels, 50)
        sizes = {len(p) for p in parts}
        assert len(sizes) == 1  # equal-size silos

    def test_dirichlet_partition_covers_disjointly_with_unequal_sizes(self):
        rng = np.random.default_rng(3)
        labels = rng.integers(0, 10, size=4000)
        parts = dirichlet_label_partition(rng, labels, 8, alpha=0.3)
        allidx = np.concatenate(parts)
        assert len(allidx) == 4000
        assert len(np.unique(allidx)) == 4000
        # Small alpha: silo sizes must be genuinely unequal.
        sizes = [len(p) for p in parts]
        assert np.std(sizes) / np.mean(sizes) > 0.1

    def test_dirichlet_partition_alpha_controls_skew(self):
        """Small alpha concentrates each silo on few labels; large alpha
        approaches IID (silo label histogram ~ global histogram)."""
        rng = np.random.default_rng(4)
        labels = rng.integers(0, 10, size=20_000)

        def mean_dominant(alpha):
            parts = dirichlet_label_partition(
                np.random.default_rng(5), labels, 10, alpha=alpha)
            doms = [np.bincount(labels[p], minlength=10).max() / len(p)
                    for p in parts]
            return float(np.mean(doms))

        assert mean_dominant(0.05) > mean_dominant(100.0) + 0.2
        assert mean_dominant(100.0) < 0.2  # near-IID: ~0.1 for 10 classes

    def test_dirichlet_partition_min_per_silo(self):
        rng = np.random.default_rng(6)
        labels = rng.integers(0, 5, size=200)
        parts = dirichlet_label_partition(rng, labels, 20, alpha=0.05,
                                          min_per_silo=3)
        assert all(len(p) >= 3 for p in parts)
        assert len(np.unique(np.concatenate(parts))) == 200

    def test_pad_ragged_silos(self):
        datas = [{"x": np.arange(6.0).reshape(3, 2), "y": np.arange(3)},
                 {"x": np.arange(2.0).reshape(1, 2), "y": np.arange(1)}]
        padded = pad_ragged_silos(datas)
        assert all(d["x"].shape == (3, 2) for d in padded)
        np.testing.assert_array_equal(padded[0]["w"], [1.0, 1.0, 1.0])
        np.testing.assert_array_equal(padded[1]["w"], [1.0, 0.0, 0.0])
        # Real rows are untouched; padding repeats row 0.
        np.testing.assert_array_equal(padded[1]["x"][0], datas[1]["x"][0])
        np.testing.assert_array_equal(padded[1]["x"][1], datas[1]["x"][0])
        with pytest.raises(ValueError, match="already has"):
            pad_ragged_silos(padded)
