"""Quickstart: SFVI on a tiny hierarchical Gaussian model, federated
across 3 silos — the paper's Algorithm 1 end to end in ~60 lines of API.

Demonstrates:
  * the StructuredModel contract (eqs. (1)-(3)),
  * the structured variational family q(Z_G) prod_j q(Z_Lj | Z_G),
  * the hub-and-spoke runtime (Server/Silo) with metered communication,
  * partition invariance: the federated result equals the centralized one.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ConditionalGaussian,
    DiagGaussian,
    SFVIProblem,
    SFVIServer,
    Silo,
    StructuredModel,
)
from repro.optim.adam import adam

# ---------------------------------------------------------------------------
# Model: mu ~ N(0, 10^2); b_j | mu ~ N(mu, 1); y_jk | b_j ~ N(b_j, 0.5^2)
# Z_G = mu (global), Z_Lj = b_j (one latent per silo), theta = {} (fully
# Bayesian). The exact posterior is Gaussian, so we can check the answer.
# ---------------------------------------------------------------------------

N_SILOS, N_OBS = 3, 40
rng = np.random.default_rng(0)
true_mu = 2.0
true_b = rng.normal(true_mu, 1.0, N_SILOS)
data = [jnp.asarray(rng.normal(true_b[j], 0.5, N_OBS)) for j in range(N_SILOS)]


def log_prior_global(theta, z_G):
    return -0.5 * jnp.sum(z_G**2) / 10.0**2


def log_local(theta, z_G, z_L, y_j):
    lp_b = -0.5 * jnp.sum((z_L - z_G) ** 2)  # b_j | mu ~ N(mu, 1)
    ll = -0.5 * jnp.sum((y_j - z_L) ** 2) / 0.5**2
    return lp_b + ll


model = StructuredModel(
    global_dim=1, local_dim=1,
    log_prior_global=log_prior_global, log_local=log_local,
    name="hierarchical_gaussian",
)

problem = SFVIProblem(
    model=model,
    global_family=DiagGaussian(1),
    local_family=ConditionalGaussian(dim=1, global_dim=1, use_coupling=True),
)

key = jax.random.PRNGKey(0)
silos = [
    Silo(
        silo_id=j,
        problem=problem,
        data=data[j],
        eta_L=problem.local_family.init(jax.random.fold_in(key, j)),
        local_optimizer=adam(5e-2),
        num_obs=N_OBS,
        seed=j,
    )
    for j in range(N_SILOS)
]
server = SFVIServer(
    problem, silos, theta={},
    eta_G=problem.global_family.init(jax.random.fold_in(key, 100)),
    optimizer=adam(5e-2),
)
history = server.run(num_iters=2000)
print(f"ELBO: start {history['elbo'][0]:.1f} -> end {history['elbo'][-1]:.1f}")

mu_hat = float(server.eta_G["mu"][0])
sigma_hat = float(jnp.exp(server.eta_G["log_sigma"][0]))
print(f"\nq(mu) = N({mu_hat:.3f}, {sigma_hat:.3f}^2)   [true mu = {true_mu}]")
print(f"communication: {server.comm.rounds} rounds, "
      f"{server.comm.bytes_up/1e3:.1f} kB up, {server.comm.bytes_down/1e3:.1f} kB down")

# Closed-form check: posterior of mu given silo means (integrating b_j).
ybar = np.array([float(d.mean()) for d in data])
var_j = 1.0 + 0.5**2 / N_OBS  # var of ybar_j | mu (same for every silo)
post_prec = 1 / 10.0**2 + N_SILOS / var_j
post_mu = np.sum(ybar) / var_j / post_prec
print(f"exact posterior: N({post_mu:.3f}, {np.sqrt(1/post_prec):.3f}^2)")
assert abs(mu_hat - post_mu) < 0.15, "SFVI should match the exact posterior"
print("OK: SFVI matches the analytic posterior.")
