"""Quickstart: SFVI on a tiny hierarchical Gaussian model, federated
across 3 silos — the paper's Algorithm 1 end to end through the
declarative experiment API.

One :class:`ExperimentSpec` describes the whole run (model by registry
name, scenario, optimizers, seed) and serializes to JSON; ``build``
assembles the compiled runtime (all silos advance inside one
``shard_map`` graph); ``save``/``resume`` checkpoint the full round
state — the second half of the run below continues from disk and lands
on EXACTLY the state an uninterrupted run reaches.

Model (registered as "toy"):
    mu ~ N(0, 10^2)            — global Z_G
    b_j | mu ~ N(mu, 1)        — one local latent per silo Z_Lj
    y_jk | b_j ~ N(b_j, 0.5^2) — 40 observations per silo
The exact posterior of mu is Gaussian, so we can check the answer.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro.federated import (Experiment, ExperimentSpec, ModelSpec,
                             OptimizerSpec, Scenario, build)

# ---------------------------------------------------------------------------
# 1. Declare the experiment. 80 rounds x 25 local steps of SFVI = 2000
#    optimizer steps, synchronizing after every step (Algorithm 1).
# ---------------------------------------------------------------------------
spec = ExperimentSpec(
    model=ModelSpec("toy", {"num_obs": 40}),
    scenario=Scenario(algorithm="sfvi"),
    num_silos=3,
    rounds=80,
    local_steps=25,
    server_opt=OptimizerSpec("adam", 5e-2),
    seed=0,
)
print("spec (JSON-serializable, reproducible):")
print("  " + spec.to_json(indent=0).replace("\n", " ")[:76] + "...")

# ---------------------------------------------------------------------------
# 2. Build and run the first half; checkpoint; resume from disk; finish.
#    Resume is bit-exact: every random stream is a function of
#    (seed, absolute round), and save/resume round-trips the full state.
# ---------------------------------------------------------------------------
exp = build(spec)
first_half = exp.run(40)
elbo_start = first_half["elbo"][0]
ckpt = tempfile.mkdtemp(prefix="sfvi_quickstart_")
exp.save(ckpt)
print(f"\ncheckpointed at round {exp.round} -> {ckpt}")

exp = Experiment.resume(ckpt)  # rebuilds from spec.json + restores state
history = exp.run()            # the remaining 40 rounds
print(f"resumed and finished at round {exp.round}/{spec.rounds}")
print(f"ELBO: start {elbo_start:.1f} -> end {history['elbo'][-1]:.1f}")

# ---------------------------------------------------------------------------
# 3. Check the answer against the closed-form posterior (staged by the
#    registry next to the data) and report the metered communication.
# ---------------------------------------------------------------------------
mu_hat = float(np.asarray(exp.eta_G["mu"])[0])
sigma_hat = float(np.exp(np.asarray(exp.eta_G["log_sigma"])[0]))
post_mu = exp.bundle.extras["posterior_mu"]
post_sd = exp.bundle.extras["posterior_sd"]
true_mu = exp.bundle.extras["true_mu"]

print(f"\nq(mu) = N({mu_hat:.3f}, {sigma_hat:.3f}^2)   [true mu = {true_mu}]")
print(f"exact posterior: N({post_mu:.3f}, {post_sd:.3f}^2)")
print(f"communication: {exp.comm.rounds} rounds, "
      f"{exp.comm.bytes_up/1e3:.1f} kB up, {exp.comm.bytes_down/1e3:.1f} kB down")

assert abs(mu_hat - post_mu) < 0.15, "SFVI should match the exact posterior"
print("OK: SFVI matches the analytic posterior.")
