"""Paper §4.2: federated ProdLDA topic modelling across 3 silos.

Fits the ProdLDA generative model with SFVI (global topics T live on the
server; per-document weights W_k never leave their silo) and reports
per-topic UMass coherence, mirroring Figure 2 on a synthetic corpus.

Run:  PYTHONPATH=src:. python examples/prodlda_topics.py
"""
from benchmarks.bench_prodlda import run


def main():
    res = run(quick=True, iters_scale=2.0)
    coh = res["coherence"]
    print("\n== ProdLDA median topic coherence (UMass; higher is better) ==")
    for k, v in coh.items():
        print(f"  {k:>12s}: {v:.3f}")
    # The paper's §4.2 findings, reproduced:
    #   (i) the communication-efficient SFVI-Avg yields the most coherent
    #       topics, beating both SFVI and independent per-silo fits;
    #  (ii) SFVI attains the higher ELBO nevertheless (Fig. 2b).
    assert coh["SFVI-Avg"] > coh["Independent"], (
        "SFVI-Avg should beat per-silo independent fits (paper Fig. 2a)")
    assert res["elbo_sfvi"] > res["elbo_avg"] - 5e3, (
        "SFVI's ELBO should be at least comparable (paper Fig. 2b)")
    print("OK: reproduces the paper's coherence/ELBO ordering (Fig. 2).")


if __name__ == "__main__":
    main()
