"""Paper §4.2: federated ProdLDA topic modelling across 3 silos, driven
through the declarative experiment API (``repro.federated.api``) over the
compiled runtime.

Fits the ProdLDA generative model with SFVI (global topics T live on the
server; per-document weights W_k never leave their silo), with SFVI-Avg,
and with independent per-silo fits, then reports per-topic UMass
coherence — mirroring Figure 2 on a synthetic corpus.

``--dp-noise z`` adds a differentially private SFVI-Avg fit (topics are
learned under per-silo clip + Gaussian noise, docs/privacy.md) and
reports the coherence it retains next to its (ε, δ).

Run:  PYTHONPATH=src:. python examples/prodlda_topics.py [--dp-noise 0.5]
"""
import argparse
import dataclasses

import numpy as np

from repro.federated import (ExperimentSpec, ModelSpec, OptimizerSpec,
                             Scenario, build)
from repro.models.paper.prodlda import umass_coherence
from repro.models.paper.registry import get_model

J = 3
LR = 5e-2


def fit(bundle, *, num_silos, seed, algorithm, rounds, local_steps,
        dp_noise=0.0, dp_clip=1.0):
    spec = ExperimentSpec(
        model=ModelSpec("prodlda"),
        scenario=Scenario(algorithm=algorithm, dp_noise=dp_noise,
                          dp_clip=dp_clip, dp_delta=1e-5),
        num_silos=num_silos, rounds=rounds, local_steps=local_steps,
        server_opt=OptimizerSpec("adam", LR), seed=seed,
        data_seed=0,  # the bundle below is staged at seed 0
    )
    exp = build(spec, bundle=bundle)
    hist = exp.run()
    return exp, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="also fit DP SFVI-Avg at this noise multiplier")
    ap.add_argument("--dp-clip", type=float, default=1.0)
    args = ap.parse_args()

    bundle = get_model("prodlda").build(0, J)
    lda, counts = bundle.extras["lda"], bundle.extras["counts"]

    def silo_bundle(j):
        """One silo fitting alone (the paper's per-silo baseline)."""
        return dataclasses.replace(
            bundle, datas=[bundle.datas[j]], num_obs=[bundle.num_obs[j]])

    # Equal local-step budgets: 600 steps each; SFVI syncs every step,
    # SFVI-Avg every 25 (24 rounds), independent silos never.
    exp_sfvi, hist_sfvi = fit(bundle, num_silos=J, seed=1, algorithm="sfvi",
                              rounds=24, local_steps=25)
    exp_avg, hist_avg = fit(bundle, num_silos=J, seed=1, algorithm="sfvi_avg",
                            rounds=24, local_steps=25)
    indep = [fit(silo_bundle(j), num_silos=1, seed=1 + 10 * j,
                 algorithm="sfvi_avg", rounds=1, local_steps=600)[0]
             for j in range(J)]

    def coherence_of(eta_G):
        t = np.asarray(lda.topics(eta_G["mu"]))
        return umass_coherence(t, np.asarray(counts), top_n=8)

    coh = {
        "SFVI": float(np.median(coherence_of(exp_sfvi.eta_G))),
        "SFVI-Avg": float(np.median(coherence_of(exp_avg.eta_G))),
        "Independent": float(np.median(
            np.concatenate([coherence_of(e.eta_G) for e in indep]))),
    }
    exp_dp = None
    if args.dp_noise > 0:
        exp_dp, _ = fit(bundle, num_silos=J, seed=1, algorithm="sfvi_avg",
                        rounds=24, local_steps=25,
                        dp_noise=args.dp_noise, dp_clip=args.dp_clip)
        coh["SFVI-Avg+DP"] = float(np.median(coherence_of(exp_dp.eta_G)))

    print("\n== ProdLDA median topic coherence (UMass; higher is better) ==")
    for k, v in coh.items():
        print(f"  {k:>12s}: {v:.3f}")
    if exp_dp is not None:
        delta = exp_dp.spec.scenario.dp_delta
        eps, _ = exp_dp.accountant.epsilon(delta)
        print(f"  SFVI-Avg+DP is ({eps:.2f}, {delta:g})-DP "
              f"(z={args.dp_noise:g}, C={args.dp_clip:g})")
    print("\n== communication (same 600-local-step budget) ==")
    for name, exp in [("SFVI", exp_sfvi), ("SFVI-Avg", exp_avg)]:
        print(f"  {name:>12s}: {exp.comm.total/2**20:6.1f} MiB total "
              f"({exp.comm.per_round/2**20:.2f} MiB/round)")

    # The paper's §4.2 findings, reproduced:
    #   (i) the communication-efficient SFVI-Avg yields the most coherent
    #       topics, beating independent per-silo fits;
    #  (ii) SFVI attains a comparable-or-higher ELBO nevertheless (Fig. 2b).
    assert coh["SFVI-Avg"] > coh["Independent"], (
        "SFVI-Avg should beat per-silo independent fits (paper Fig. 2a)")
    assert hist_sfvi["elbo"][-1] > hist_avg["elbo"][-1] - 5e3, (
        "SFVI's ELBO should be at least comparable (paper Fig. 2b)")
    print("OK: reproduces the paper's coherence/ELBO ordering (Fig. 2).")


if __name__ == "__main__":
    main()
