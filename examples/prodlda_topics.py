"""Paper §4.2: federated ProdLDA topic modelling across 3 silos, driven
through the compiled federated runtime (``repro.federated``).

Fits the ProdLDA generative model with SFVI (global topics T live on the
server; per-document weights W_k never leave their silo), with SFVI-Avg,
and with independent per-silo fits, then reports per-topic UMass
coherence — mirroring Figure 2 on a synthetic corpus.

``--dp-noise z`` adds a differentially private SFVI-Avg fit (topics are
learned under per-silo clip + Gaussian noise, docs/privacy.md) and
reports the coherence it retains next to its (ε, δ).

Run:  PYTHONPATH=src:. python examples/prodlda_topics.py [--dp-noise 0.5]
"""
import argparse

import jax
import numpy as np

from repro.federated import PrivacyPolicy, Server
from repro.models.paper.fixtures import prodlda_federation
from repro.models.paper.prodlda import init_theta, umass_coherence
from repro.optim import adam

J = 3
LR = 5e-2


def fit(lda, datas, *, seed, algorithm, rounds, local_steps, privacy=None):
    prob = lda.problem
    srv = Server(
        prob, datas, init_theta(),
        prob.global_family.init(jax.random.PRNGKey(seed)),
        num_obs=[lda.docs_per_silo] * len(datas),
        server_opt=adam(LR),
        local_opt=adam(LR),
        privacy=privacy,
        seed=seed,
    )
    hist = srv.run(rounds, algorithm=algorithm, local_steps=local_steps)
    return srv, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="also fit DP SFVI-Avg at this noise multiplier")
    ap.add_argument("--dp-clip", type=float, default=1.0)
    args = ap.parse_args()

    lda, datas, counts = prodlda_federation(seed=0, num_silos=J)

    # Equal local-step budgets: 600 steps each; SFVI syncs every step,
    # SFVI-Avg every 25 (24 rounds), independent silos never.
    srv_sfvi, hist_sfvi = fit(lda, datas, seed=1, algorithm="sfvi",
                              rounds=24, local_steps=25)
    srv_avg, hist_avg = fit(lda, datas, seed=1, algorithm="sfvi_avg",
                            rounds=24, local_steps=25)
    indep = [fit(lda, [datas[j]], seed=1 + 10 * j, algorithm="sfvi_avg",
                 rounds=1, local_steps=600)[0] for j in range(J)]

    def coherence_of(eta_G):
        t = np.asarray(lda.topics(eta_G["mu"]))
        return umass_coherence(t, np.asarray(counts), top_n=8)

    coh = {
        "SFVI": float(np.median(coherence_of(srv_sfvi.eta_G))),
        "SFVI-Avg": float(np.median(coherence_of(srv_avg.eta_G))),
        "Independent": float(np.median(
            np.concatenate([coherence_of(s.eta_G) for s in indep]))),
    }
    srv_dp = None
    if args.dp_noise > 0:
        policy = PrivacyPolicy(clip_norm=args.dp_clip,
                               noise_multiplier=args.dp_noise, delta=1e-5)
        srv_dp, _ = fit(lda, datas, seed=1, algorithm="sfvi_avg",
                        rounds=24, local_steps=25, privacy=policy)
        coh["SFVI-Avg+DP"] = float(np.median(coherence_of(srv_dp.eta_G)))

    print("\n== ProdLDA median topic coherence (UMass; higher is better) ==")
    for k, v in coh.items():
        print(f"  {k:>12s}: {v:.3f}")
    if srv_dp is not None:
        eps, _ = srv_dp.accountant.epsilon(srv_dp.privacy.delta)
        print(f"  SFVI-Avg+DP is ({eps:.2f}, {srv_dp.privacy.delta:g})-DP "
              f"(z={args.dp_noise:g}, C={args.dp_clip:g})")
    print("\n== communication (same 600-local-step budget) ==")
    for name, srv in [("SFVI", srv_sfvi), ("SFVI-Avg", srv_avg)]:
        print(f"  {name:>12s}: {srv.comm.total/2**20:6.1f} MiB total "
              f"({srv.comm.per_round/2**20:.2f} MiB/round)")

    # The paper's §4.2 findings, reproduced:
    #   (i) the communication-efficient SFVI-Avg yields the most coherent
    #       topics, beating independent per-silo fits;
    #  (ii) SFVI attains a comparable-or-higher ELBO nevertheless (Fig. 2b).
    assert coh["SFVI-Avg"] > coh["Independent"], (
        "SFVI-Avg should beat per-silo independent fits (paper Fig. 2a)")
    assert hist_sfvi["elbo"][-1] > hist_avg["elbo"][-1] - 5e3, (
        "SFVI's ELBO should be at least comparable (paper Fig. 2b)")
    print("OK: reproduces the paper's coherence/ELBO ordering (Fig. 2).")


if __name__ == "__main__":
    main()
