"""Paper §4.1: hierarchical Bayesian neural network on heterogeneous data,
trained with SFVI and with SFVI-Avg — the paper's headline experiment,
driven through the compiled federated runtime (``repro.federated``): all
silos advance inside one ``shard_map`` graph, and the communication meter
reports the §3.2 efficiency claim directly.

``--dp-noise z`` additionally runs a differentially private SFVI-Avg fit
(per-silo clip + Gaussian noise inside the compiled round, docs/privacy.md)
and reports its (ε, δ) next to the accuracy it costs.

Run:  PYTHONPATH=src:. python examples/federated_bnn.py [--silos 5] [--fedpop]
      PYTHONPATH=src:. python examples/federated_bnn.py --dp-noise 1.0
"""
import argparse

import jax

from repro.federated import PrivacyPolicy, Server
from repro.models.paper.fixtures import bnn_posterior_accuracy, hier_bnn_federation
from repro.optim import adam


def fit(bnn, train, *, seed, algorithm, rounds, local_steps, lr=2e-2,
        privacy=None):
    prob = bnn.problem
    srv = Server(
        prob, train, {}, prob.global_family.init(jax.random.PRNGKey(seed)),
        server_opt=adam(lr), local_opt=adam(lr), privacy=privacy, seed=seed,
    )
    srv.run(rounds, algorithm=algorithm, local_steps=local_steps)
    return srv


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--silos", type=int, default=4)
    ap.add_argument("--fedpop", action="store_true",
                    help="fully-Bayesian FedPop variant (Table 1, row 2)")
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="also fit a DP SFVI-Avg variant at this noise "
                         "multiplier (0 = skip)")
    ap.add_argument("--dp-clip", type=float, default=1.0)
    args = ap.parse_args()

    bnn, train, test = hier_bnn_federation(
        seed=0, num_silos=args.silos, fedpop=args.fedpop)
    # Equal optimizer-step budget: SFVI syncs every step, SFVI-Avg every 15.
    srv_sfvi = fit(bnn, train, seed=0, algorithm="sfvi", rounds=10,
                   local_steps=15)
    srv_avg = fit(bnn, train, seed=0, algorithm="sfvi_avg", rounds=10,
                  local_steps=15)

    fits = [("SFVI", srv_sfvi), ("SFVI-Avg", srv_avg)]
    if args.dp_noise > 0:
        policy = PrivacyPolicy(clip_norm=args.dp_clip,
                               noise_multiplier=args.dp_noise, delta=1e-5)
        srv_dp = fit(bnn, train, seed=0, algorithm="sfvi_avg", rounds=10,
                     local_steps=15, privacy=policy)
        fits.append(("SFVI-Avg+DP", srv_dp))

    print("\n== test accuracy across silos ==")
    results = {}
    for name, srv in fits:
        acc, std = bnn_posterior_accuracy(bnn, srv.eta_G, srv.eta_L, test)
        results[name] = (acc, srv)
        priv = ""
        if srv.accountant is not None:
            eps, _ = srv.accountant.epsilon(srv.privacy.delta)
            priv = f"  ({eps:.2f}, {srv.privacy.delta:g})-DP"
        print(f"  {name:>11s}: {100*acc:5.1f}% (std {100*std:.2f})  "
              f"{srv.comm.rounds} rounds, {srv.comm.total/2**20:.1f} MiB total "
              f"comm ({srv.comm.per_round/2**20:.2f} MiB/round){priv}")

    assert results["SFVI"][0] > 0.5, "SFVI should beat random chance comfortably"
    ratio = srv_sfvi.comm.total / max(srv_avg.comm.total, 1)
    print(f"\nSFVI-Avg reaches {100*results['SFVI-Avg'][0]:.1f}% with "
          f"{ratio:.0f}x less communication for the same local-step budget "
          f"(the paper's communication-efficiency claim).")


if __name__ == "__main__":
    main()
