"""Paper §4.1: hierarchical Bayesian neural network on heterogeneous data,
trained with SFVI and with SFVI-Avg — the paper's headline experiment in
example form (synthetic MNIST-shaped data; 90% single-label silos).

Run:  PYTHONPATH=src:. python examples/federated_bnn.py [--silos 5] [--fedpop]
"""
import argparse

from benchmarks.bench_hier_bnn import run_once


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--silos", type=int, default=4)
    ap.add_argument("--fedpop", action="store_true",
                    help="fully-Bayesian FedPop variant (Table 1, row 2)")
    args = ap.parse_args()

    res = run_once(seed=0, fedpop=args.fedpop, num_silos=args.silos, quick=True)
    print("\n== test accuracy across silos ==")
    for name, (acc, std, rounds, comm) in res.items():
        print(f"  {name:>9s}: {100*acc:5.1f}% (std {100*std:.2f})  "
              f"{rounds} rounds, {comm/2**20:.1f} MiB total comm")
    sfvi_acc = res["SFVI"][0]
    avg_acc, _, avg_rounds, _ = res["SFVI-Avg"]
    assert sfvi_acc > 0.5, "SFVI should beat random chance comfortably"
    print(f"\nSFVI-Avg reaches {100*avg_acc:.1f}% in only {avg_rounds} "
          f"communication rounds (the paper's communication-efficiency claim).")


if __name__ == "__main__":
    main()
