"""Paper §4.1: hierarchical Bayesian neural network on heterogeneous data,
trained with SFVI and with SFVI-Avg — the paper's headline experiment,
driven through the declarative experiment API (``repro.federated.api``):
each fit is one serializable :class:`ExperimentSpec` built into an
:class:`Experiment` over the compiled runtime (all silos advance inside
one ``shard_map`` graph), and the communication meter reports the §3.2
efficiency claim directly.

``--dp-noise z`` additionally runs a differentially private SFVI-Avg fit
(per-silo clip + Gaussian noise inside the compiled round, docs/privacy.md)
and reports its (ε, δ) next to the accuracy it costs.

Run:  PYTHONPATH=src:. python examples/federated_bnn.py [--silos 5] [--fedpop]
      PYTHONPATH=src:. python examples/federated_bnn.py --dp-noise 1.0
"""
import argparse

from repro.federated import (ExperimentSpec, ModelSpec, OptimizerSpec,
                             Scenario, build)
from repro.models.paper.fixtures import bnn_posterior_accuracy
from repro.models.paper.registry import get_model


def fit(model_name, bundle, *, num_silos, seed, algorithm, rounds,
        local_steps, lr=2e-2, dp_noise=0.0, dp_clip=1.0):
    spec = ExperimentSpec(
        model=ModelSpec(model_name),
        scenario=Scenario(algorithm=algorithm, dp_noise=dp_noise,
                          dp_clip=dp_clip, dp_delta=1e-5),
        num_silos=num_silos, rounds=rounds, local_steps=local_steps,
        server_opt=OptimizerSpec("adam", lr), seed=seed,
    )
    exp = build(spec, bundle=bundle)
    exp.run()
    return exp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--silos", type=int, default=4)
    ap.add_argument("--fedpop", action="store_true",
                    help="fully-Bayesian FedPop variant (Table 1, row 2)")
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="also fit a DP SFVI-Avg variant at this noise "
                         "multiplier (0 = skip)")
    ap.add_argument("--dp-clip", type=float, default=1.0)
    args = ap.parse_args()

    model_name = "fedpop_bnn" if args.fedpop else "hier_bnn"
    bundle = get_model(model_name).build(0, args.silos)
    bnn, test = bundle.extras["bnn"], bundle.extras["test"]
    # Equal optimizer-step budget: SFVI syncs every step, SFVI-Avg every 15.
    common = dict(num_silos=args.silos, seed=0, rounds=10, local_steps=15)
    exp_sfvi = fit(model_name, bundle, algorithm="sfvi", **common)
    exp_avg = fit(model_name, bundle, algorithm="sfvi_avg", **common)

    fits = [("SFVI", exp_sfvi), ("SFVI-Avg", exp_avg)]
    if args.dp_noise > 0:
        exp_dp = fit(model_name, bundle, algorithm="sfvi_avg",
                     dp_noise=args.dp_noise, dp_clip=args.dp_clip, **common)
        fits.append(("SFVI-Avg+DP", exp_dp))

    print("\n== test accuracy across silos ==")
    results = {}
    for name, exp in fits:
        acc, std = bnn_posterior_accuracy(bnn, exp.eta_G, exp.eta_L, test)
        results[name] = (acc, exp)
        priv = ""
        if exp.accountant is not None:
            delta = exp.spec.scenario.dp_delta
            eps, _ = exp.accountant.epsilon(delta)
            priv = f"  ({eps:.2f}, {delta:g})-DP"
        print(f"  {name:>11s}: {100*acc:5.1f}% (std {100*std:.2f})  "
              f"{exp.comm.rounds} rounds, {exp.comm.total/2**20:.1f} MiB total "
              f"comm ({exp.comm.per_round/2**20:.2f} MiB/round){priv}")

    assert results["SFVI"][0] > 0.5, "SFVI should beat random chance comfortably"
    ratio = exp_sfvi.comm.total / max(exp_avg.comm.total, 1)
    print(f"\nSFVI-Avg reaches {100*results['SFVI-Avg'][0]:.1f}% with "
          f"{ratio:.0f}x less communication for the same local-step budget "
          f"(the paper's communication-efficiency claim).")


if __name__ == "__main__":
    main()
