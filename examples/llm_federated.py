"""End-to-end driver on an assigned LLM architecture: SFVI-train a reduced
model for a few hundred steps, then serve it with batched requests using
the posterior-mean weights + per-silo Bayesian head adapters.

This is the framework path the dry-run lowers at production scale
(launch/steps.py); here it RUNS on CPU with the reduced config.

Run:  PYTHONPATH=src python examples/llm_federated.py --arch qwen3-4b \
          --steps 200
      PYTHONPATH=src python examples/llm_federated.py --arch olmoe-1b-7b \
          --steps 30 --batch 4        # MoE variant, quicker
"""
import argparse

from repro.launch import serve_backbone as serve_mod
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    print("== phase 1: SFVI training ==")
    train_mod.main([
        "--arch", args.arch, "--steps", str(args.steps),
        "--batch", str(args.batch), "--silos", "4",
    ])
    print("\n== phase 2: batched serving (posterior-mean model) ==")
    serve_mod.main([
        "--arch", args.arch, "--batch", str(args.batch),
        "--prompt-len", "32", "--gen", "16", "--silos", "4",
    ])


if __name__ == "__main__":
    main()
