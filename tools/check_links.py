"""Offline relative-link checker for README.md and docs/*.md.

    python tools/check_links.py [root]

Verifies every markdown link target that is not an external URL or a
pure in-page anchor resolves to an existing file relative to the
document. Runs locally and in CI (the ``docs-link-check`` job) — it
used to live as a heredoc inside the workflow, where it could neither
be executed locally nor linted.

Exit codes: 0 all links resolve, 1 broken links (listed on stdout).
"""
from __future__ import annotations

import pathlib
import re
import sys

_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)\s]*)?\)")
_EXTERNAL = re.compile(r"^[a-z]+://")


def broken_links(root: pathlib.Path) -> list:
    """All dangling relative links under ``root`` (README + docs/)."""
    docs = [
        root / "README.md",
        # rglob so nested doc trees are covered; __pycache__ (and any
        # other cache dir a stray interpreter run leaves behind) is
        # never documentation — skip it explicitly.
        *sorted(p for p in (root / "docs").rglob("*.md")
                if "__pycache__" not in p.parts),
    ]
    bad = []
    for md in docs:
        if not md.exists():
            continue
        base = md.parent
        for m in _LINK.finditer(md.read_text()):
            target = m.group(1)
            if _EXTERNAL.match(target):
                continue  # external URL; the offline check skips these
            if not (base / target).exists():
                bad.append(f"{md.relative_to(root)}: broken link -> {target}")
    return bad


def main(argv) -> int:
    root = pathlib.Path(argv[1]) if len(argv) > 1 else pathlib.Path(".")
    bad = broken_links(root)
    print("\n".join(bad) if bad else "all relative links resolve")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
