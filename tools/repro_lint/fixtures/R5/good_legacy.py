# virtual-path: tests/_legacy_server.py
# The frozen pre-refactor oracle is definitionally algorithm-specific
# and exempt from R5 (and R6) — see docs/dev.md.


def sfvi_round(state):
    algo = "sfvi_avg"
    return state, algo
