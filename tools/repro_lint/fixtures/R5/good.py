# virtual-path: src/repro/federated/runtime.py
"""Round driver fixture.

Docstrings may discuss sfvi_avg or fed_ep freely — only code literals
couple the runtime to a registry entry.
"""


def round_body(strategy, state, weights):
    """Delegates combine to the strategy — even pvi-specific damping."""
    return strategy.combine(state, weights)
