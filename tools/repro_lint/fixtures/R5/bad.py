# virtual-path: src/repro/federated/runtime.py


def round_body(strategy, state):
    if strategy.name == "sfvi_avg":  # LINT-HIT
        return state
    sfvi_lr = 0.1  # LINT-HIT
    return state, sfvi_lr  # LINT-HIT


def pvi_update(state):  # LINT-HIT
    return state
