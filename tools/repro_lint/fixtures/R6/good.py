# virtual-path: src/repro/federated/aggregation.py
import jax
import jax.sharding
import numpy as np


def combine(agg, comp, x):
    if isinstance(x, (jax.Array, np.ndarray)):  # data type, not a protocol
        x = x + 1
    codec = getattr(comp, "wire_codec", "custom")  # documented capability
    if codec == "int8":
        return x * 2
    reduction = getattr(agg, "fused_reduction", None)
    return x if reduction is None else x + 1


def shim():
    return hasattr(jax.sharding, "AxisType")  # repro-lint: allow[R6] — fixture: jax cross-version feature shim, not a protocol probe
