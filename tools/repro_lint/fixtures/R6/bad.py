# virtual-path: src/repro/federated/aggregation.py


def combine(agg, comp, x, MeanAggregator, Int8Compressor):
    if hasattr(x, "shape"):  # LINT-HIT
        x = x + 1
    if isinstance(agg, MeanAggregator):  # LINT-HIT
        return x
    if type(comp) is Int8Compressor:  # LINT-HIT
        return x * 2
    return x
