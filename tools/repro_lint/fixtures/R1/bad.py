# virtual-path: src/repro/federated/scheduler.py
import jax


def invite(seed, r):
    key = jax.random.PRNGKey(seed)  # LINT-HIT
    return jax.random.bernoulli(key, 0.5, (4,))  # LINT-HIT


def noise(shape):
    return jax.random.normal(jax.random.PRNGKey(0), shape)  # LINT-HIT
