# virtual-path: src/repro/models/paper/fixtures.py
# Staging modules (models/, data/, the async latency model) own their
# seeds: roots are legal here without pragmas.
import numpy as np


def synth(seed):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(3,))
