# virtual-path: src/repro/federated/scheduler.py
import jax


def invite(key, r):
    round_key = jax.random.fold_in(key, r)
    return jax.random.bernoulli(round_key, 0.5, (4,))


def staged(seed):
    base = jax.random.PRNGKey(seed)  # repro-lint: allow[R1] — fixture: root of the invite stream, folded per round below
    base = jax.random.fold_in(base, 0)
    return base
