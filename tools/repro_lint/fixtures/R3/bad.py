# virtual-path: src/repro/federated/runtime.py
from functools import partial

import jax


@jax.jit
def step(x, y):
    if x > 0:  # LINT-HIT
        return y
    assert y.sum() > 0  # LINT-HIT
    return x


@partial(jax.jit, static_argnames=("mode",))
def run(x, mode=[]):  # LINT-HIT
    while x:  # LINT-HIT
        x = x - 1
    return x


def build():
    def body(x):
        if x:  # LINT-HIT
            return x
        return -x

    return jax.jit(body)
