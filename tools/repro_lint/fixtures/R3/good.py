# virtual-path: src/repro/federated/runtime.py
from functools import partial

import jax
import jax.numpy as jnp


@jax.jit
def step(x, y):
    if x is None:  # optional-arg plumbing is a trace-time constant
        return y
    if x.ndim == 2:  # shape metadata is static under tracing
        return x + y
    return jnp.where(x > 0, x, y)


@partial(jax.jit, static_argnames=("mode",))
def run(x, mode="fast"):
    if mode == "fast":  # static arg: branching is legal and hashable
        return x
    return x * 2


def host(x):
    if x > 0:  # not a jitted scope
        return x
    return -x
