# virtual-path: src/repro/kernels/wire.py
import jax
import numpy as np

STATS = {}


def kernel(x):
    print("tracing", x)  # LINT-HIT
    global STATS  # LINT-HIT
    STATS = {"n": 1}
    host = np.asarray(x)  # LINT-HIT
    return host.sum().item()  # LINT-HIT


def debug_tap(x):
    jax.debug.print("x={}", x)  # LINT-HIT
    return x
