# virtual-path: src/repro/kernels/wire.py
import jax
import jax.numpy as jnp
import numpy as np


def kernel(x):
    return jnp.asarray(x).sum()


def host_loop(fn, state):
    # Explicit device_get is the sanctioned pull: transfer-guard clean.
    state, metrics = fn(state)
    elbo = jax.device_get(metrics["elbo"])
    return state, float(elbo[-1])


def staging(num_obs):
    return np.asarray(num_obs, np.float32)  # repro-lint: allow[R4] — fixture: host staging of a Python list at init, not a device pull
