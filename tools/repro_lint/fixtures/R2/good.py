# virtual-path: src/repro/federated/runtime.py
import jax


def ship(comp, privacy, upload, axis):
    noisy = privacy.privatize(upload)
    coded = comp.encode(noisy)
    return jax.lax.all_gather(coded, axis)


def gather_only(tree, axis):
    # Non-DP helper: no privatization in scope, so ordering is moot.
    return jax.lax.all_gather(tree, axis)


def manifest(msg):
    # String codecs are not wire compressors.
    return msg.encode("utf-8")
