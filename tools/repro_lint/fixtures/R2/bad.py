# virtual-path: src/repro/federated/runtime.py
import jax


def ship_encode_first(comp, privacy, upload, axis):
    coded = comp.encode(upload)  # LINT-HIT
    noisy = privacy.privatize(coded)
    return jax.lax.all_gather(noisy, axis)


def ship_gather_first(privacy, upload, axis):
    gathered = jax.lax.all_gather(upload, axis)  # LINT-HIT
    return privacy.privatize(gathered)
