# virtual-path: src/repro/federated/runtime.py
# A justified pragma (id or rule name, em dash or plain dash) is clean,
# inline or on its own line above the suppressed statement.
import jax

key = jax.random.PRNGKey(0)  # repro-lint: allow[R1] — fixture: root of a documented stream
# repro-lint: allow[rng-discipline] — fixture: standalone pragma shields the next line
key2 = jax.random.PRNGKey(1)
