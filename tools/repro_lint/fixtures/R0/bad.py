# virtual-path: src/repro/federated/runtime.py
# Reason-less pragmas are themselves violations: suppression must be
# auditable, so the engine demands the "why" on the pragma line.
import jax

key = jax.random.PRNGKey(0)  # repro-lint: allow[R1]  # LINT-HIT
