"""R3 — tracer safety.

Python control flow on a traced value inside a ``jit``/``shard_map``
scope either raises ``TracerBoolConversionError`` at first call or —
worse — silently bakes one branch into the compiled graph when the
value happens to be concrete during tracing.  Static arguments must be
hashable or every call recompiles.

Flags, inside functions that are jitted (decorator, ``jax.jit(f)`` /
``shard_map(f, ...)`` wrapping of a local def):

* ``if`` / ``while`` / ``assert`` whose condition reads a traced
  parameter directly.  Exempt: ``is None`` / ``is not None`` tests and
  parameters only touched through static metadata (``.shape``,
  ``.ndim``, ``.dtype``, ``.size``) — both are trace-time constants.
* parameters named in ``static_argnames`` whose default is a mutable
  (unhashable) literal.

Name-level only, on purpose: values *derived* from params are assumed
traced-safe to test only via jnp ops, and chasing provenance here would
trade precision for noise.  The runtime sanitizer's recompile watchdog
(src/repro/debug.py) is the dynamic backstop.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from tools.repro_lint.engine import (
    FileContext,
    Rule,
    Violation,
    call_name,
    dotted_name,
    iter_functions,
    path_in,
    register,
    scope_walk,
)

STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
JIT_TAILS = {"jit", "pmap", "shard_map"}


def _decorator_jit_info(fn: ast.AST) -> Optional[Tuple[Set[str], Set[int]]]:
    """(static_argnames, static_argnums) if fn is jit-decorated, else None."""
    for dec in getattr(fn, "decorator_list", []):
        name = dotted_name(dec) if not isinstance(dec, ast.Call) else call_name(dec)
        tail = name.rsplit(".", 1)[-1]
        if tail in JIT_TAILS:
            return set(), set()
        if isinstance(dec, ast.Call) and tail == "partial":
            inner = dec.args[0] if dec.args else None
            if inner is not None and \
                    dotted_name(inner).rsplit(".", 1)[-1] in JIT_TAILS:
                return _static_from_call(dec)
    return None


def _static_from_call(call: ast.Call) -> Tuple[Set[str], Set[int]]:
    names: Set[str] = set()
    nums: Set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    names.add(c.value)
        elif kw.arg == "static_argnums":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, int):
                    nums.add(c.value)
    return names, nums


def _locally_wrapped(tree: ast.Module) -> Set[str]:
    """Names of local defs passed to jax.jit(f)/shard_map(f, ...)."""
    wrapped: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and \
                call_name(node).rsplit(".", 1)[-1] in JIT_TAILS:
            for arg in node.args[:1]:
                if isinstance(arg, ast.Name):
                    wrapped.add(arg.id)
    return wrapped


def _traced_params(fn, static_names: Set[str], static_nums: Set[int]) -> Set[str]:
    args = fn.args
    ordered = [a.arg for a in args.posonlyargs + args.args]
    traced = set(ordered) | {a.arg for a in args.kwonlyargs}
    traced.discard("self")
    traced -= static_names
    for i in static_nums:
        if 0 <= i < len(ordered):
            traced.discard(ordered[i])
    return traced


def _offending_names(test: ast.AST, traced: Set[str]) -> List[Tuple[ast.Name, str]]:
    """Traced-param Name reads in a condition, after exemptions."""
    exempt: Set[int] = set()
    for node in ast.walk(test):
        if isinstance(node, ast.Compare) and \
                all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops) and \
                all(isinstance(c, ast.Constant) and c.value is None
                    for c in node.comparators):
            for sub in ast.walk(node):
                exempt.add(id(sub))
        if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
            for sub in ast.walk(node):
                exempt.add(id(sub))
    out = []
    for node in ast.walk(test):
        if isinstance(node, ast.Name) and node.id in traced \
                and id(node) not in exempt:
            out.append((node, node.id))
    return out


@register
class TracerSafety(Rule):
    id = "R3"
    name = "tracer-safety"
    summary = ("no Python if/while/assert on traced params in jit/shard_map "
               "scopes; static args must be hashable")

    def applies(self, path: str) -> bool:
        return path_in(path, "src/repro/", "tests/")

    def check(self, ctx: FileContext) -> List[Violation]:
        out: List[Violation] = []
        wrapped = _locally_wrapped(ctx.tree)
        for fn, qualname in iter_functions(ctx.tree):
            info = _decorator_jit_info(fn)
            if info is None and fn.name in wrapped:
                info = (set(), set())
            if info is None:
                continue
            static_names, static_nums = info
            out.extend(self._check_unhashable_defaults(ctx, fn, qualname,
                                                       static_names))
            traced = _traced_params(fn, static_names, static_nums)
            for node in scope_walk(fn):
                conds: Sequence[Tuple[ast.AST, str]] = ()
                if isinstance(node, (ast.If, ast.While)):
                    conds = ((node.test, type(node).__name__.lower()),)
                elif isinstance(node, ast.Assert):
                    conds = ((node.test, "assert"),)
                for test, kind in conds:
                    for name_node, pname in _offending_names(test, traced):
                        out.append(self.violation(
                            ctx, node,
                            f"Python `{kind}` on traced parameter "
                            f"`{pname}` in {qualname}() — use jnp.where/"
                            "lax.cond, or mark the arg static"))
        return out

    def _check_unhashable_defaults(self, ctx, fn, qualname,
                                   static_names: Set[str]) -> List[Violation]:
        out: List[Violation] = []
        args = fn.args
        pos = args.posonlyargs + args.args
        defaults: Dict[str, ast.AST] = {}
        for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults, strict=True):
            defaults[a.arg] = d
        for a, d in zip(args.kwonlyargs, args.kw_defaults, strict=True):
            if d is not None:
                defaults[a.arg] = d
        for pname in static_names & set(defaults):
            if isinstance(defaults[pname], (ast.List, ast.Dict, ast.Set)):
                out.append(self.violation(
                    ctx, defaults[pname],
                    f"static arg `{pname}` of {qualname}() defaults to an "
                    "unhashable literal — jit static args must be hashable "
                    "(use a tuple/frozenset/None)"))
        return out
