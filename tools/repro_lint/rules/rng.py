"""R1 — RNG discipline.

Bit-exact save→resume (and the paper's subsampled-RDP accounting) both
rest on one property: every random stream in the runtime is a pure
function of ``(seed, round, step, silo)``.  That holds iff PRNG *roots*
(``jax.random.PRNGKey`` / ``np.random.default_rng``) are created only in
staging code — model/data initialization and the async latency model —
and everything inside the compiled federated path derives its keys by
``fold_in`` from a key it was handed.

Two checks:

* **roots** — a PRNG root constructor anywhere in ``src/repro/`` outside
  the allowlisted staging modules must carry a pragma explaining which
  stream it roots and why that is resume-sound.
* **fold-in chain** — inside ``federated/`` and ``kernels/``, a
  ``jax.random.<draw>`` whose key argument is (or is locally assigned
  from) a fresh ``PRNGKey`` never mixes in round/step/silo indices: two
  rounds would replay identical noise.  Derive via ``fold_in`` instead.
"""

from __future__ import annotations

import ast
from typing import List

from tools.repro_lint.engine import (
    FileContext,
    Rule,
    Violation,
    call_name,
    iter_functions,
    path_in,
    register,
    scope_walk,
)

ROOT_CALLS = (
    "jax.random.PRNGKey",
    "random.PRNGKey",
    "np.random.default_rng",
    "numpy.random.default_rng",
    "np.random.seed",
    "numpy.random.seed",
)

# Staging modules that legitimately create roots: model/problem fixtures,
# data synthesis/partitioning, and the async engine's latency model.
ROOT_ALLOWED = (
    "src/repro/models/",
    "src/repro/data/",
    "src/repro/federated/async_engine.py",
)

FOLD_SCOPES = ("src/repro/federated/", "src/repro/kernels/")


def _is_root_call(node: ast.Call) -> bool:
    name = call_name(node)
    return any(name == r or name.endswith("." + r) for r in ROOT_CALLS)


@register
class RngDiscipline(Rule):
    id = "R1"
    name = "rng-discipline"
    summary = ("PRNG roots only in staging modules; federated/kernel draws "
               "must derive keys via fold_in, never a fresh PRNGKey")

    def applies(self, path: str) -> bool:
        return path_in(path, "src/repro/")

    def check(self, ctx: FileContext) -> List[Violation]:
        out: List[Violation] = []
        if not path_in(ctx.path, *ROOT_ALLOWED):
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.Call) and _is_root_call(node):
                    out.append(self.violation(
                        ctx, node,
                        f"PRNG root `{call_name(node)}` outside staging "
                        "modules — derive from a handed-in key with "
                        "fold_in, or pragma with the stream it roots"))
        if path_in(ctx.path, *FOLD_SCOPES):
            out.extend(self._check_fold_chain(ctx))
        return out

    # -- fold-in chain ----------------------------------------------------

    def _check_fold_chain(self, ctx: FileContext) -> List[Violation]:
        out: List[Violation] = []
        for fn, qualname in iter_functions(ctx.tree):
            fresh = self._fresh_key_names(fn)
            for node in scope_walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                if not name.startswith("jax.random.") or not node.args:
                    continue
                tail = name.rsplit(".", 1)[1]
                if tail in ("PRNGKey", "fold_in", "key"):
                    continue
                key = node.args[0]
                if isinstance(key, ast.Call) and _is_root_call(key):
                    out.append(self.violation(
                        ctx, node,
                        f"jax.random.{tail} keyed on a fresh PRNGKey in "
                        f"{qualname}() — fold the round/step/silo indices "
                        "in (fold_in) so the stream is resume-pure"))
                elif isinstance(key, ast.Name) and key.id in fresh:
                    out.append(self.violation(
                        ctx, node,
                        f"jax.random.{tail} keyed on `{key.id}`, assigned "
                        f"from a fresh PRNGKey in {qualname}() — derive it "
                        "via fold_in instead"))
        return out

    @staticmethod
    def _fresh_key_names(fn: ast.AST) -> set:
        """Local names whose (only) assignments are direct PRNGKey calls.

        One-hop provenance only — deliberately shallow.  A name that is
        ever reassigned from anything else (``k = fold_in(k, r)``) is
        considered laundered and drops out.
        """
        fresh: set = set()
        assigns = sorted(
            (n for n in scope_walk(fn) if isinstance(n, ast.Assign)),
            key=lambda n: n.lineno)
        for node in assigns:
            targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if not targets:
                continue
            if isinstance(node.value, ast.Call) and _is_root_call(node.value):
                fresh.update(targets)
            else:
                fresh.difference_update(targets)
        return fresh
