"""R6 — protocol probes.

PR 5 replaced runtime ``isinstance``/``hasattr`` type sniffing with the
:class:`VariationalFamily` protocol, and PR 7 did the same for
strategies.  Probes regress that: they silently mask typos (``hasattr``
swallows *any* missing attribute), freeze concrete types into generic
code, and hide capability contracts that belong on the protocol.  The
sanctioned patterns are (a) a documented protocol attribute read with
``getattr(obj, "cap", default)`` — a typo'd capability then *visibly*
falls back — and (b) the one documented structural fallback in
``core/family.py``.

Flags, in ``src/`` and ``tests/`` outside the exempt files:

* any ``hasattr(...)`` call
* ``isinstance(x, P)`` / ``type(x) is P`` where ``P`` is one of the
  repo's protocol/capability types (families, strategies, aggregators,
  compressors) — checks against plain data types (dict, bytes,
  jax.Array...) are not probes and stay legal.
"""

from __future__ import annotations

import ast
from typing import List

from tools.repro_lint.engine import (
    FileContext,
    Rule,
    Violation,
    dotted_name,
    path_in,
    register,
)

# The documented structural fallback + the frozen pre-refactor oracle.
EXEMPT = ("src/repro/core/family.py", "tests/_legacy_server.py")

# Protocol/capability types: probing these is type-sniffing a protocol.
PROTOCOL_TYPES = {
    "VariationalFamily", "DiagGaussian", "CholeskyGaussian",
    "BatchedDiagGaussian", "LowRankGaussian", "ConditionalGaussian",
    "FamilySpec",
    "ServerStrategy", "StrategySpec",
    "Aggregator", "MeanAggregator", "TrimmedMeanAggregator",
    "Compressor", "NoCompression", "Int8Compressor",
}


def _protocol_types_in(node: ast.AST) -> List[str]:
    names = []
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Name, ast.Attribute)):
            tail = dotted_name(sub).rsplit(".", 1)[-1]
            if tail in PROTOCOL_TYPES:
                names.append(tail)
    return names


@register
class ProtocolProbes(Rule):
    id = "R6"
    name = "protocol-probes"
    summary = ("no hasattr()/isinstance/type-is probes of protocol types "
               "outside family.py's documented fallback")

    def applies(self, path: str) -> bool:
        return path_in(path, "src/repro/", "tests/") and path not in EXEMPT

    def check(self, ctx: FileContext) -> List[Violation]:
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name == "hasattr":
                    out.append(self.violation(
                        ctx, node,
                        "hasattr() probe — read the documented protocol "
                        "attribute with getattr(obj, name, default), or "
                        "pragma a version shim"))
                elif name == "isinstance" and len(node.args) == 2:
                    hits = _protocol_types_in(node.args[1])
                    if hits:
                        out.append(self.violation(
                            ctx, node,
                            f"isinstance probe of protocol type(s) "
                            f"{', '.join(sorted(set(hits)))} — dispatch "
                            "through the protocol, not the concrete class"))
            elif isinstance(node, ast.Compare) and \
                    any(isinstance(op, (ast.Is, ast.Eq)) for op in node.ops):
                left = node.left
                if isinstance(left, ast.Call) and \
                        dotted_name(left.func) == "type":
                    hits = []
                    for comp in node.comparators:
                        hits += _protocol_types_in(comp)
                    if hits:
                        out.append(self.violation(
                            ctx, node,
                            f"`type(x) is {hits[0]}` exact-type probe — use "
                            "a protocol capability attribute instead"))
        return out
