"""R4 — purity of compiled modules.

``runtime.py``, ``strategy.py``, and ``kernels/*`` assemble code that
runs *inside* ``jit``/``shard_map``/Pallas traces.  Host effects there
either fire at trace time (once, silently — a print that "works" on the
first round and never again), force device→host syncs that stall the
round pipeline, or desynchronize with the actual execution.  The
sanctioned idioms: metrics leave the graph as return values; the host
loop pulls them with an *explicit* ``jax.device_get`` (transfer-guard
clean — see src/repro/debug.py); debugging goes through the sanitizer
harness, not ad-hoc callbacks.

Flags, module-wide in the compiled modules:

* ``print`` and host-callback escapes (``jax.debug.print``,
  ``jax.debug.callback``, ``jax.pure_callback``, ``io_callback``,
  ``host_callback``)
* ``global`` statements (trace-time mutation of module state)
* host pulls: ``.item()``, ``np.asarray``/``np.array``/``np.copy`` —
  use ``jax.device_get`` in host loops, ``jnp.*`` in traced code;
  genuinely host-side staging gets a pragma.
"""

from __future__ import annotations

import ast
from typing import List

from tools.repro_lint.engine import (
    FileContext,
    Rule,
    Violation,
    call_name,
    path_in,
    register,
)

COMPILED_MODULES = (
    "src/repro/federated/runtime.py",
    "src/repro/federated/strategy.py",
    "src/repro/kernels/",
)

CALLBACK_NAMES = (
    "jax.debug.print",
    "jax.debug.callback",
    "jax.pure_callback",
    "jax.experimental.io_callback",
    "io_callback",
    "host_callback",
)

HOST_PULL_CALLS = ("np.asarray", "np.array", "np.copy",
                   "numpy.asarray", "numpy.array", "numpy.copy")


@register
class CompiledPurity(Rule):
    id = "R4"
    name = "compiled-purity"
    summary = ("no print/host callbacks/global mutation/.item()/np.asarray "
               "in runtime.py, strategy.py, kernels/*")

    def applies(self, path: str) -> bool:
        return path_in(path, *COMPILED_MODULES)

    def check(self, ctx: FileContext) -> List[Violation]:
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Global):
                out.append(self.violation(
                    ctx, node,
                    "`global` mutation in a compiled module — keep state "
                    "in the carry or on the host object"))
                continue
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name == "print":
                out.append(self.violation(
                    ctx, node,
                    "print() in a compiled module fires at trace time, not "
                    "per round — return the value as a metric instead"))
            elif name in CALLBACK_NAMES or \
                    name.rsplit(".", 1)[-1] in ("io_callback",) or \
                    name.startswith("host_callback."):
                out.append(self.violation(
                    ctx, node,
                    f"host callback `{name}` in a compiled module — "
                    "debugging goes through repro.debug.sanitize()"))
            elif name in HOST_PULL_CALLS:
                out.append(self.violation(
                    ctx, node,
                    f"`{name}` is a host pull — use jax.device_get in host "
                    "loops / jnp.* in traced code, or pragma host staging"))
            elif name.endswith(".item") and not node.args:
                out.append(self.violation(
                    ctx, node,
                    "`.item()` forces a device→host sync inside a compiled "
                    "module — return the array and device_get on the host"))
        return out
