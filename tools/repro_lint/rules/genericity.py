"""R5 — strategy genericity.

PR 7 made the compiled round strategy-agnostic: ``runtime.py`` drives
any registered :class:`ServerStrategy` through its hooks and must never
branch on *which* algorithm is running — that is exactly the coupling
the registry refactor removed, and the property the old source-grep
test (`tests/test_strategies.py`) protected for SFVI only.  This rule
generalizes it: no algorithm-name literal (string constant, identifier,
attribute, or parameter name) may appear in the strategy-generic
runtime modules, for *any* registry entry, current or future.

``tests/_legacy_server.py`` is the frozen pre-refactor oracle — it is
definitionally algorithm-specific and exempt (see docs/dev.md).

The name list is maintained here rather than imported from
``repro.federated.strategy`` so the linter stays importable without
jax; extend it when registering a new strategy (the fixture selftest
reminds you how).
"""

from __future__ import annotations

import ast
import re
from typing import List

from tools.repro_lint.engine import (
    FileContext,
    Rule,
    Violation,
    docstring_lines,
    path_in,
    register,
)

# Keep in sync with the @register_strategy entries in
# src/repro/federated/strategy.py.
ALGORITHM_NAMES = ("sfvi", "sfvi_avg", "pvi", "fed_ep")

# Modules that must stay strategy-generic.
GENERIC_MODULES = (
    "src/repro/federated/runtime.py",
    "src/repro/federated/async_engine.py",
    "src/repro/federated/aggregation.py",
    "src/repro/federated/metering.py",
)

EXEMPT = ("tests/_legacy_server.py",)

_WORD = re.compile("|".join(re.escape(a) for a in
                            sorted(ALGORITHM_NAMES, key=len, reverse=True)))


def _hits(text: str) -> List[str]:
    return _WORD.findall(text.lower())


@register
class StrategyGenericity(Rule):
    id = "R5"
    name = "strategy-genericity"
    summary = ("no algorithm-name literals (sfvi/pvi/fed_ep/...) in the "
               "strategy-generic runtime modules")

    def applies(self, path: str) -> bool:
        return path_in(path, *GENERIC_MODULES) and path not in EXEMPT

    def check(self, ctx: FileContext) -> List[Violation]:
        out: List[Violation] = []
        doc_lines = docstring_lines(ctx.tree)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Constant) and isinstance(node.value, str):
                if node.lineno in doc_lines:
                    continue
                for hit in _hits(node.value):
                    out.append(self.violation(
                        ctx, node,
                        f"algorithm name {hit!r} in a string literal — the "
                        "runtime must stay strategy-generic; dispatch "
                        "through the ServerStrategy registry"))
            elif isinstance(node, ast.Name) and _hits(node.id):
                out.append(self.violation(
                    ctx, node,
                    f"identifier `{node.id}` names an algorithm — the "
                    "runtime must not special-case registry entries"))
            elif isinstance(node, ast.Attribute) and _hits(node.attr):
                out.append(self.violation(
                    ctx, node,
                    f"attribute `.{node.attr}` names an algorithm — "
                    "dispatch through strategy hooks instead"))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and _hits(node.name):
                out.append(self.violation(
                    ctx, node,
                    f"function `{node.name}` names an algorithm in a "
                    "strategy-generic module"))
        return out
