"""R2 — privacy ordering.

The RDP accountant's guarantee (docs/privacy.md, Heikkilä et al.,
arXiv:2209.11595) is stated for the *transmitted* message: per-silo L2
clip + Gaussian noise must be applied before the upload is compressed
and before it crosses the wire in the all-gather.  Noise-after-compress
(or gather-then-noise) silently voids the (ε, δ) ledger while every
test on ELBO trajectories keeps passing.

The check is an intra-function ordering approximation of the dataflow
rule: in any ``src/repro/federated/`` function that both privatizes and
encodes/gathers, the first privatization call must precede every
compressor ``.encode`` and every all-gather.  Functions that never
privatize (non-DP helpers, the gather primitive itself) are out of
scope — the rule guards the *ordering* of the DP pipeline, not DP
coverage.
"""

from __future__ import annotations

import ast
from typing import List, Tuple

from tools.repro_lint.engine import (
    FileContext,
    Rule,
    Violation,
    call_name,
    iter_functions,
    path_in,
    register,
    scope_walk,
)

# Calls that apply (or contain) the clip+noise stage.
PRIVATIZE_TAILS = ("privatize", "_ship_upload", "_fused_ship")
# Calls that put bits on the wire or transform the message for the wire.
GATHER_TAILS = ("all_gather", "_coalesced_all_gather")
ENCODE_TAIL = "encode"
# ``.encode`` receivers that are string codecs, not wire compressors.
ENCODE_IGNORE_RECV = {"json", "str"}


def _events(fn: ast.AST) -> List[Tuple[int, str, str]]:
    out: List[Tuple[int, str, str]] = []
    for node in scope_walk(fn):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        tail = name.rsplit(".", 1)[-1]
        if tail in PRIVATIZE_TAILS:
            out.append((node.lineno, "priv", name))
        elif any(tail == g for g in GATHER_TAILS):
            out.append((node.lineno, "gather", name))
        elif tail == ENCODE_TAIL:
            recv = name.rsplit(".", 2)[0] if name.count(".") else ""
            if recv not in ENCODE_IGNORE_RECV and not isinstance(
                    getattr(node.func, "value", None), ast.Constant):
                out.append((node.lineno, "encode", name))
    out.sort()
    return out


@register
class PrivacyOrdering(Rule):
    id = "R2"
    name = "privacy-ordering"
    summary = ("DP clip+noise must precede compressor.encode and the "
               "all-gather inside any federated function that privatizes")

    def applies(self, path: str) -> bool:
        return path_in(path, "src/repro/federated/")

    def check(self, ctx: FileContext) -> List[Violation]:
        out: List[Violation] = []
        for fn, qualname in iter_functions(ctx.tree):
            events = _events(fn)
            privs = [e for e in events if e[1] == "priv"]
            if not privs:
                continue
            first_priv = privs[0][0]
            for line, kind, name in events:
                if kind in ("gather", "encode") and line < first_priv:
                    out.append(self.violation(
                        ctx, line,
                        f"`{name}` at line {line} precedes the first "
                        f"privatization (line {first_priv}) in {qualname}() "
                        "— clip+noise must dominate compression and the "
                        "gather or the RDP ledger is unsound"))
        return out
