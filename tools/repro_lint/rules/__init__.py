"""Rule modules — importing this package registers every rule.

To add a rule: create a module here subclassing
:class:`tools.repro_lint.engine.Rule`, decorate it with ``@register``,
import it below, add ``fixtures/<ID>/bad.py`` + ``good.py``, and
document it in docs/dev.md.
"""

from tools.repro_lint.rules import (  # noqa: F401
    genericity,
    privacy_order,
    probes,
    purity,
    rng,
    tracer,
)
