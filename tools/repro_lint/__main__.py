import sys

from tools.repro_lint.engine import main

sys.exit(main())
