"""repro-lint: AST checks for this repo's load-bearing invariants.

The repo guarantees a handful of properties only by construction — DP
clip+noise before compression and the gather, every random stream a pure
function of ``(seed, round, step, silo)``, a strategy-generic compiled
round, no ad-hoc protocol probes.  ``repro-lint`` turns each of those
conventions into an enforced rule:

    python -m tools.repro_lint src tests        # lint (CI gate)
    python -m tools.repro_lint --selftest       # run the rule fixtures
    python -m tools.repro_lint --list-rules     # what is checked and why

Violations are suppressed per line with a justified pragma::

    key = jax.random.PRNGKey(seed)  # repro-lint: allow[R1] — root of the round stream

A pragma without a reason is itself a violation.  See docs/dev.md for
the rule catalogue and the policy on when to fix vs. when to pragma.

The package is dependency-free on purpose (stdlib ``ast`` only): the CI
static-analysis job runs it without installing jax.
"""

from tools.repro_lint.engine import (  # noqa: F401
    FileContext,
    Rule,
    Violation,
    iter_py_files,
    lint_paths,
    registered_rules,
)
