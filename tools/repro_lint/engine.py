"""Rule engine: file model, pragma parsing, registry, runner.

Design notes
------------
* Rules are AST visitors over a :class:`FileContext`; they never import
  repo code, so the linter runs in a bare-stdlib environment.
* Paths are normalized to posix form relative to the lint root (the
  current working directory).  Rules scope themselves with
  :func:`path_in` prefix matching — e.g. ``path_in(path,
  "src/repro/federated/")``.
* Suppression is per line: ``# repro-lint: allow[R1] — reason`` on the
  flagged line, or on its own comment line immediately above.  Rule ids
  ("R1") and names ("rng-discipline") both work; a pragma with no
  reason is reported as rule R0.
"""

from __future__ import annotations

import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

# ``—`` (em dash) is the documented separator; plain ``-``/``--`` are
# accepted so pragmas survive editors that strip non-ASCII.
PRAGMA_RE = re.compile(
    r"#\s*repro-lint:\s*allow\[([^\]]*)\]\s*(?:(?:—|–|--|-)\s*(\S.*))?"
)


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: ``path:line: RULE[name] message``."""

    rule: str  # "R1"
    rule_name: str  # "rng-discipline"
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}[{self.rule_name}] {self.message}"


@dataclasses.dataclass
class Pragma:
    line: int  # line the pragma appears on
    target: int  # line it suppresses
    rules: Set[str]  # lowercased ids/names; "*" allowed
    reason: Optional[str]


@dataclasses.dataclass
class FileContext:
    """A parsed source file plus its suppression table."""

    path: str  # posix, relative to lint root
    source: str
    tree: ast.Module
    lines: List[str]
    pragmas: List[Pragma]
    _by_target: Dict[int, Set[str]] = dataclasses.field(default_factory=dict)

    @classmethod
    def parse(cls, path: str, source: str) -> FileContext:
        tree = ast.parse(source, filename=path)
        lines = source.splitlines()
        pragmas = _collect_pragmas(lines)
        by_target: Dict[int, Set[str]] = {}
        for p in pragmas:
            by_target.setdefault(p.target, set()).update(p.rules)
        return cls(path=path, source=source, tree=tree, lines=lines,
                   pragmas=pragmas, _by_target=by_target)

    def suppressed(self, rule: Rule, line: int) -> bool:
        toks = self._by_target.get(line)
        if not toks:
            return False
        return bool(toks & {"*", rule.id.lower(), rule.name.lower()})


def _collect_pragmas(lines: Sequence[str]) -> List[Pragma]:
    out: List[Pragma] = []
    for i, raw in enumerate(lines, start=1):
        m = PRAGMA_RE.search(raw)
        if not m:
            continue
        rules = {t.strip().lower() for t in m.group(1).split(",") if t.strip()}
        reason = m.group(2).strip() if m.group(2) else None
        before = raw[: raw.index("#")].strip() if "#" in raw else ""
        # A standalone comment line shields the next line; an inline
        # pragma shields its own.
        target = i + 1 if not before else i
        out.append(Pragma(line=i, target=target, rules=rules, reason=reason))
    return out


class Rule:
    """Base class: subclass, set id/name/docs, implement ``check``."""

    id = ""  # "R1"
    name = ""  # "rng-discipline"
    summary = ""  # one line for --list-rules

    def applies(self, path: str) -> bool:
        return True

    def check(self, ctx: FileContext) -> List[Violation]:  # pragma: no cover
        raise NotImplementedError

    def violation(self, ctx: FileContext, node_or_line, message: str) -> Violation:
        line = node_or_line if isinstance(node_or_line, int) else node_or_line.lineno
        return Violation(rule=self.id, rule_name=self.name, path=ctx.path,
                         line=line, message=message)


_REGISTRY: Dict[str, Rule] = {}


def register(cls):
    """Class decorator adding a rule instance to the global registry."""
    inst = cls()
    if inst.id in _REGISTRY:  # defensive: duplicate ids corrupt pragma semantics
        raise ValueError(f"duplicate rule id {inst.id}")
    _REGISTRY[inst.id] = inst
    return cls


def registered_rules() -> List[Rule]:
    import tools.repro_lint.rules  # noqa: F401  (side-effect: registration)

    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


# ---------------------------------------------------------------------------
# shared AST helpers (used by the rule modules)
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> str:
    """``jax.random.PRNGKey`` for an Attribute/Name chain, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    if parts:  # e.g. ``something().attr`` — keep the attr tail
        return "." + ".".join(reversed(parts))
    return ""


def call_name(node: ast.Call) -> str:
    return dotted_name(node.func)


def path_in(path: str, *prefixes: str) -> bool:
    return any(path == p or path.startswith(p) for p in prefixes)


def iter_functions(tree: ast.Module) -> Iterator[Tuple[ast.AST, str]]:
    """Yield every (def node, qualname) including nested defs."""

    def walk(node: ast.AST, prefix: str) -> Iterator[Tuple[ast.AST, str]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = f"{prefix}{child.name}"
                yield child, qn
                yield from walk(child, f"{qn}.")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
            else:
                yield from walk(child, prefix)

    yield from walk(tree, "")


def scope_walk(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk a def's body without descending into nested defs/classes.

    ``iter_functions`` yields nested defs separately, so per-function
    rules pair the two to analyze each lexical scope exactly once.
    """
    stack: List[ast.AST] = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(node))


def docstring_lines(tree: ast.Module) -> Set[int]:
    """Line numbers covered by module/class/function docstrings."""
    covered: Set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            body = getattr(node, "body", [])
            if body and isinstance(body[0], ast.Expr) and \
                    isinstance(body[0].value, ast.Constant) and \
                    isinstance(body[0].value.value, str):
                c = body[0].value
                covered.update(range(c.lineno, (c.end_lineno or c.lineno) + 1))
    return covered


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

SKIP_DIRS = {"__pycache__", ".git", "fixtures"}


def iter_py_files(paths: Sequence[str]) -> Iterator[Path]:
    for p in paths:
        root = Path(p)
        if root.is_file() and root.suffix == ".py":
            yield root
            continue
        for f in sorted(root.rglob("*.py")):
            if not SKIP_DIRS.intersection(f.parts):
                yield f


def lint_file(path: Path, rules: Sequence[Rule],
              rel_to: Optional[Path] = None,
              virtual_path: Optional[str] = None) -> List[Violation]:
    source = path.read_text()
    rel = virtual_path or _relpath(path, rel_to)
    try:
        ctx = FileContext.parse(rel, source)
    except SyntaxError as e:
        return [Violation("R0", "parse", rel, e.lineno or 1,
                          f"could not parse: {e.msg}")]
    out: List[Violation] = []
    for pragma in ctx.pragmas:
        if pragma.reason is None:
            out.append(Violation(
                "R0", "pragma-reason", rel, pragma.line,
                "pragma without a reason: write "
                "`# repro-lint: allow[RULE] — why this is sound`"))
    for rule in rules:
        if not rule.applies(rel):
            continue
        for v in rule.check(ctx):
            if not ctx.suppressed(rule, v.line):
                out.append(v)
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def _relpath(path: Path, rel_to: Optional[Path]) -> str:
    base = rel_to or Path.cwd()
    try:
        return path.resolve().relative_to(base.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def lint_paths(paths: Sequence[str], rules: Optional[Sequence[Rule]] = None,
               rel_to: Optional[Path] = None) -> List[Violation]:
    rules = list(rules) if rules is not None else registered_rules()
    out: List[Violation] = []
    for f in iter_py_files(paths):
        out.extend(lint_file(f, rules, rel_to=rel_to))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m tools.repro_lint",
        description=__doc__.splitlines()[0] if __doc__ else "repro-lint")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories to lint (default: src tests)")
    ap.add_argument("--selftest", action="store_true",
                    help="run every rule against its positive/negative fixtures")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    rules = registered_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.id}[{r.name}] {r.summary}")
        return 0
    if args.selftest:
        from tools.repro_lint.selftest import run_selftest

        return run_selftest()

    violations = lint_paths(args.paths or ["src", "tests"], rules)
    for v in violations:
        print(v.render())
    if violations:
        print(f"repro-lint: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    return 0
