"""Fixture-driven self-test: every rule proves it fires and stays quiet.

Layout: ``fixtures/<RULE_ID>/bad*.py`` (positive — must flag exactly the
lines marked ``# LINT-HIT``) and ``fixtures/<RULE_ID>/good*.py``
(negative — must produce zero violations; these double as documentation
of the sanctioned idioms, including justified pragmas).

Each fixture declares the path it pretends to live at::

    # virtual-path: src/repro/federated/runtime.py

so path-scoped rules apply.  ``fixtures/R0`` exercises the engine's own
pragma machinery (reason-less pragmas are violations).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List

from tools.repro_lint.engine import lint_file, registered_rules

FIXTURES = Path(__file__).parent / "fixtures"
VPATH_RE = re.compile(r"#\s*virtual-path:\s*(\S+)")


def _expected_lines(source: str) -> List[int]:
    return [i for i, line in enumerate(source.splitlines(), start=1)
            if "# LINT-HIT" in line]


def run_selftest() -> int:
    rules = {r.id: r for r in registered_rules()}
    failures: List[str] = []
    checked = 0
    for rule_dir in sorted(FIXTURES.iterdir()):
        if not rule_dir.is_dir():
            continue
        rid = rule_dir.name
        if rid != "R0" and rid not in rules:
            failures.append(f"{rule_dir}: fixture dir for unknown rule {rid}")
            continue
        active = [rules[rid]] if rid != "R0" else []
        fixture_files = sorted(rule_dir.glob("*.py"))
        if not any(f.name.startswith("bad") for f in fixture_files) or \
                not any(f.name.startswith("good") for f in fixture_files):
            failures.append(
                f"{rid}: every rule needs at least one bad*.py (positive) "
                "and one good*.py (negative) fixture")
        for f in fixture_files:
            checked += 1
            source = f.read_text()
            m = VPATH_RE.search(source)
            if not m:
                failures.append(f"{f}: missing `# virtual-path:` header")
                continue
            got = {v.line for v in lint_file(f, active, virtual_path=m.group(1))
                   if v.rule == rid}
            want = set(_expected_lines(source))
            if f.name.startswith("good") and want:
                failures.append(f"{f}: good fixtures must not mark LINT-HIT")
            if got != want:
                failures.append(
                    f"{f}: {rid} flagged lines {sorted(got)}, fixture "
                    f"expects {sorted(want)}")
    for rid in rules:
        if not (FIXTURES / rid).is_dir():
            failures.append(f"{rid}: no fixture directory")
    for msg in failures:
        print(f"SELFTEST FAIL: {msg}", file=sys.stderr)
    print(f"repro-lint selftest: {checked} fixtures, "
          f"{len(failures)} failure(s)")
    return 1 if failures else 0
